"""TablePack — every table a model needs, fused into ONE device artifact.

The paper keeps each function's table resident in BRAM next to its consumer
(Sec. 7.2); a network, however, evaluates a *set* of nonlinearities (gelu for
the MLP, sigmoid/tanh for gates, exp for softmax...), and shipping one table +
one kernel dispatch per function multiplies both the VMEM residency and the
dispatch overhead by F.  A :class:`TablePack` concatenates all range values
into a single ``values`` vector and stores selector metadata as (F, n_max)
padded planes (see :class:`repro.core.packing.PackLayout`), so

  * ONE artifact stays VMEM-resident for the whole network (BRAM instantiation
    lifted to the function-set level), and
  * ONE fused Pallas kernel — ``repro.kernels.table_pack_lookup`` — serves any
    member function via a static ``fn_id`` row index.

``eval_pack_ref`` is the pure-jnp oracle; it reproduces the per-table
``eval_table_ref`` BIT FOR BIT (same compare/gather/FMA sequence on the same
f32 values; the pack only rebases the BRAM addresses), which the parity tests
assert for every registered function.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flow import cached_table
from repro.core.packing import (PackLayout, PolyPackLayout, QuantPackLayout,
                                ShardedPackLayout, pack_layout,
                                poly_pack_layout, quant_pack_layout,
                                shard_pack_layout)
from repro.core.quantize import plan_quant_member
from repro.core.table import TableSpec

from .jax_table import select_interval


def _member_id(names: Tuple[str, ...], fn) -> int:
    """Resolve a name or integer fn_id to a VALIDATED member index.

    Both unknown names and out-of-range integers raise ``KeyError`` naming the
    offender and listing the registered members — the raw tuple-index
    ``IndexError`` this replaces said neither.
    """
    if isinstance(fn, str):
        try:
            return names.index(fn)
        except ValueError:
            raise KeyError(f"function {fn!r} not in pack {names}") from None
    fid = int(fn)
    if not 0 <= fid < len(names):
        raise KeyError(
            f"fn_id {fid} out of range for pack with {len(names)} members "
            f"{names}") from None
    return fid


class TablePack(NamedTuple):
    """Device-ready multi-function table artifact (all array leaves jnp, f32)."""

    names: Tuple[str, ...]  # static: member function names (fn_id order)
    n_intervals: Tuple[int, ...]  # static: real sub-interval count per member
    boundaries: jax.Array  # (F, n_max+1) f32, right-padded +inf
    inv_delta: jax.Array  # (F, n_max)   f32
    delta: jax.Array  # (F, n_max)   f32
    base: jax.Array  # (F, n_max)   f32 — GLOBAL packed-values index (exact < 2^24)
    seg_count: jax.Array  # (F, n_max)   f32
    values: jax.Array  # (M,)         f32 — all member tables, concatenated

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def n_max(self) -> int:
        return self.inv_delta.shape[1]

    @property
    def footprint(self) -> int:
        return self.values.shape[0]

    def fn_id(self, name: str) -> int:
        return _member_id(self.names, name)

    def member_id(self, fn) -> int:
        """Name or integer fn_id -> validated index (KeyError otherwise)."""
        return _member_id(self.names, fn)

    def routing_scalars(self) -> Tuple[np.ndarray, ...]:
        """Prefetched scalar operands for dynamic fn_id dispatch: ``(n_arr,)``
        with ``n_arr[f]`` the real sub-interval count of member ``f``."""
        return (np.asarray(self.n_intervals, dtype=np.int32),)


def from_layout(layout: PackLayout, dtype=jnp.float32) -> TablePack:
    if layout.footprint >= (1 << 24):
        raise ValueError("pack footprint exceeds f32 exact-integer range")
    return TablePack(
        names=layout.names,
        n_intervals=layout.n_intervals,
        boundaries=jnp.asarray(layout.boundaries, dtype=dtype),
        inv_delta=jnp.asarray(layout.inv_delta, dtype=dtype),
        delta=jnp.asarray(layout.delta, dtype=dtype),
        base=jnp.asarray(layout.base.astype(np.float64), dtype=dtype),
        seg_count=jnp.asarray(layout.seg_count.astype(np.float64), dtype=dtype),
        values=jnp.asarray(layout.values, dtype=dtype),
    )


def pack_specs(specs: Sequence[TableSpec]) -> TablePack:
    """Pack already-built TableSpecs (order defines fn_id)."""
    return from_layout(pack_layout(specs))


def build_pack(
    names: Sequence[str],
    e_a: float,
    *,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    intervals: Optional[dict] = None,
) -> TablePack:
    """Run the design flow for every name and fuse the artifacts into one pack."""
    intervals = intervals or {}
    specs = []
    for name in names:
        lo, hi = intervals.get(name, (None, None))
        specs.append(cached_table(name, e_a, lo, hi, algorithm=algorithm,
                                  omega=omega))
    return pack_specs(specs)


def _resolve(pack, fn) -> int:
    return pack.member_id(fn)


def _select_pack_params(pack: TablePack, fid: int, xf: jax.Array):
    """One selector + four gathers against function ``fid``'s metadata row."""
    brow = pack.boundaries[fid]
    j = select_interval(brow, pack.n_intervals[fid], xf)
    p = jnp.take(brow, j, axis=0)
    invd = jnp.take(pack.inv_delta[fid], j, axis=0)
    base = jnp.take(pack.base[fid], j, axis=0)
    segs = jnp.take(pack.seg_count[fid], j, axis=0)
    return p, invd, base, segs


def eval_pack_ref(pack: TablePack, fn, x: jax.Array, *,
                  extrapolate: bool = False) -> jax.Array:
    """Pure-jnp pack evaluation — bit-identical to per-table ``eval_table_ref``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_pack_params(pack, fid, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(pack.values, a, axis=0)
    y1 = jnp.take(pack.values, a + 1, axis=0)
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    return (y0 + t * (y1 - y0)).astype(dtype)


def eval_pack_slope(pack: TablePack, fn, x: jax.Array, *,
                    extrapolate: bool = False) -> jax.Array:
    """d/dx of the pack surrogate — bit-identical to ``eval_table_slope``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_pack_params(pack, fid, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(pack.values, a, axis=0)
    y1 = jnp.take(pack.values, a + 1, axis=0)
    slope = (y1 - y0) * invd
    if not extrapolate:
        n = pack.n_intervals[fid]
        inside = (xf >= pack.boundaries[fid, 0]) & (xf < pack.boundaries[fid, n])
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


# --------------------------------------------------------------------------------------
# QuantPack — the pack with int8/int16 entry codes, dequantized on read.
# --------------------------------------------------------------------------------------


class QuantTablePack(NamedTuple):
    """Device-ready quantized multi-function pack.

    Entries live as int8/int16 codes in two width-group vectors; the selector
    metadata plus per-sub-interval dequant params (scale, zero, ramp) are flat
    RAGGED f32 lanes — member ``fid``'s segment starts at a STATIC offset
    derived from the static ``n_intervals`` tuple, so no (F, n_max) padding is
    paid (see :class:`repro.core.packing.QuantPackLayout`).  Dequantize-on-read
    is one extra FMA per gathered endpoint: ``v = (zero + ramp*i) + scale*q``.
    """

    names: Tuple[str, ...]  # static: member function names (fn_id order)
    n_intervals: Tuple[int, ...]  # static: sub-interval count per member
    entry_bits: Tuple[int, ...]  # static: 8 | 16 → which codes vector
    rho: Tuple[float, ...]  # static: interpolation share of e_a per member
    boundaries: jax.Array  # (sum n_f+1,) f32 flat rows
    inv_delta: jax.Array  # (sum n_f,) f32
    base: jax.Array  # (sum n_f,) f32 — GLOBAL index into the width-group codes
    seg_count: jax.Array  # (sum n_f,) f32
    scale: jax.Array  # (sum n_f,) f32
    zero: jax.Array  # (sum n_f,) f32
    ramp: jax.Array  # (sum n_f,) f32
    codes8: jax.Array  # (max(M8,1),) int8
    codes16: jax.Array  # (max(M16,1),) int16

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def footprint(self) -> int:
        """Stored entries — excludes the 1-entry dummy of an unused width group,
        so it agrees with :class:`QuantPackLayout`'s accounting."""
        m8 = self.codes8.shape[0] if 8 in self.entry_bits else 0
        m16 = self.codes16.shape[0] if 16 in self.entry_bits else 0
        return int(m8 + m16)

    @property
    def footprint_bytes(self) -> int:
        m8 = self.codes8.shape[0] if 8 in self.entry_bits else 0
        m16 = self.codes16.shape[0] if 16 in self.entry_bits else 0
        return int(m8 + 2 * m16)

    def fn_id(self, name: str) -> int:
        return _member_id(self.names, name)

    def member_id(self, fn) -> int:
        """Name or integer fn_id -> validated index (KeyError otherwise)."""
        return _member_id(self.names, fn)

    def bounds_offset(self, fid: int) -> int:
        return sum(n + 1 for n in self.n_intervals[:fid])

    def lane_offset(self, fid: int) -> int:
        return sum(self.n_intervals[:fid])

    def codes_for(self, fid: int) -> jax.Array:
        return self.codes8 if self.entry_bits[fid] == 8 else self.codes16

    def routing_scalars(self) -> Tuple[np.ndarray, ...]:
        """Prefetched scalar operands for dynamic fn_id dispatch.

        The ragged static lane offsets (``bounds_offset`` / ``lane_offset``)
        and the per-member width-group choice, as int32 vectors a
        scalar-prefetch kernel indexes at runtime:
        ``(n_arr, bounds_offsets, lane_offsets, entry_bits)``.
        """
        F = self.n_functions
        return (np.asarray(self.n_intervals, dtype=np.int32),
                np.asarray([self.bounds_offset(f) for f in range(F)], np.int32),
                np.asarray([self.lane_offset(f) for f in range(F)], np.int32),
                np.asarray(self.entry_bits, dtype=np.int32))


def from_quant_layout(layout: QuantPackLayout) -> QuantTablePack:
    if max(len(layout.codes8), len(layout.codes16)) >= (1 << 24):
        raise ValueError("pack footprint exceeds f32 exact-integer range")

    def codes_arr(codes: np.ndarray, dtype) -> jax.Array:
        if len(codes) == 0:  # keep a 1-entry dummy so the operand stays valid
            return jnp.zeros((1,), dtype=dtype)
        return jnp.asarray(codes, dtype=dtype)

    f32 = lambda a: jnp.asarray(np.asarray(a, dtype=np.float64),
                                dtype=jnp.float32)
    return QuantTablePack(
        names=layout.names,
        n_intervals=layout.n_intervals,
        entry_bits=layout.entry_bits,
        rho=tuple(m.rho for m in layout.members),
        boundaries=f32(layout.boundaries),
        inv_delta=f32(layout.inv_delta),
        base=f32(layout.base),
        seg_count=f32(layout.seg_count),
        scale=f32(layout.scale),
        zero=f32(layout.zero),
        ramp=f32(layout.ramp),
        codes8=codes_arr(layout.codes8, jnp.int8),
        codes16=codes_arr(layout.codes16, jnp.int16),
    )


def build_quant_pack(
    names: Sequence[str],
    e_a: float,
    *,
    rho: float = 0.9,
    dtype: str = "auto",
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    intervals: Optional[dict] = None,
) -> QuantTablePack:
    """Error-budgeted quantized pack: interpolation gets ``rho * e_a``, code
    rounding the rest; int8 vs int16 is chosen per member (``dtype='auto'``)."""
    intervals = intervals or {}
    members = []
    for name in names:
        lo, hi = intervals.get(name, (None, None))
        members.append(plan_quant_member(
            name, e_a, lo, hi, algorithm=algorithm, omega=omega,
            rho=rho, dtype=dtype))
    return from_quant_layout(quant_pack_layout(members))


def _quant_select(pack: QuantTablePack, fid: int, xf: jax.Array):
    """Selector + seven gathers against member ``fid``'s ragged lane segment."""
    bo, lo = pack.bounds_offset(fid), pack.lane_offset(fid)
    n = pack.n_intervals[fid]
    brow = pack.boundaries[bo : bo + n + 1]
    j = select_interval(brow, n, xf)
    p = jnp.take(brow, j, axis=0)
    invd = jnp.take(pack.inv_delta[lo : lo + n], j, axis=0)
    base = jnp.take(pack.base[lo : lo + n], j, axis=0)
    segs = jnp.take(pack.seg_count[lo : lo + n], j, axis=0)
    scale = jnp.take(pack.scale[lo : lo + n], j, axis=0)
    zero = jnp.take(pack.zero[lo : lo + n], j, axis=0)
    ramp = jnp.take(pack.ramp[lo : lo + n], j, axis=0)
    return p, invd, base, segs, scale, zero, ramp


def eval_quant_pack_ref(pack: QuantTablePack, fn, x: jax.Array, *,
                        extrapolate: bool = False) -> jax.Array:
    """Pure-jnp dequantize-on-read oracle — bit-identical to the Pallas kernel."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs, scale, zero, ramp = _quant_select(pack, fid, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    codes = pack.codes_for(fid)
    c0 = jnp.take(codes, a, axis=0).astype(jnp.float32)
    c1 = jnp.take(codes, a + 1, axis=0).astype(jnp.float32)
    r = zero + ramp * i  # the chord ramp at entry i
    y0 = r + scale * c0
    y1 = (r + ramp) + scale * c1
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    return (y0 + t * (y1 - y0)).astype(dtype)


def eval_quant_pack_slope(pack: QuantTablePack, fn, x: jax.Array, *,
                          extrapolate: bool = False) -> jax.Array:
    """d/dx of the quantized surrogate: (ramp + scale * (c1 - c0)) / delta."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs, scale, zero, ramp = _quant_select(pack, fid, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    codes = pack.codes_for(fid)
    c0 = jnp.take(codes, a, axis=0).astype(jnp.float32)
    c1 = jnp.take(codes, a + 1, axis=0).astype(jnp.float32)
    slope = (ramp + scale * (c1 - c0)) * invd
    if not extrapolate:
        bo = pack.bounds_offset(fid)
        n = pack.n_intervals[fid]
        inside = ((xf >= pack.boundaries[bo]) &
                  (xf < pack.boundaries[bo + n]))
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


def make_quant_pack_fn(
    pack: QuantTablePack,
    name: str,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Differentiable unary ``f(x)`` served from the quantized pack.

    Mirrors :func:`make_pack_fn`: quantized-table-slope tangent by default,
    ``exact_d1`` for the analytic derivative, ``use_pallas=True`` for the
    fused dequantize-on-read kernel (value + slope in one selector pass on the
    training path).
    """
    fid = pack.fn_id(name)
    if use_pallas:
        from repro.kernels.table_pack_lookup import (
            quant_pack_grad_pallas, quant_pack_lookup_pallas)

        fwd_impl = lambda v: quant_pack_lookup_pallas(
            pack, fid, v, extrapolate=extrapolate)
        fused_grad = lambda v: quant_pack_grad_pallas(
            pack, fid, v, extrapolate=extrapolate)
    else:
        fwd_impl = lambda v: eval_quant_pack_ref(pack, fid, v,
                                                 extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = eval_quant_pack_slope(pack, fid, x, extrapolate=extrapolate)
        return y, slope * dx

    return f


def make_pack_fn(
    pack: TablePack,
    name: str,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Differentiable unary ``f(x)`` evaluated through the shared pack.

    Mirrors ``repro.approx.make_table_fn``: table-slope tangent by default
    (what the hardware computes), ``exact_d1`` for the analytic derivative.
    ``use_pallas=True`` routes through the fused pack kernel (one selector pass
    yields value AND slope on the training path).
    """
    fid = pack.fn_id(name)
    if use_pallas:
        from repro.kernels.table_pack_lookup import (
            table_pack_grad_pallas, table_pack_lookup_pallas)

        fwd_impl = lambda v: table_pack_lookup_pallas(
            pack, fid, v, extrapolate=extrapolate)
        fused_grad = lambda v: table_pack_grad_pallas(
            pack, fid, v, extrapolate=extrapolate)
    else:
        fwd_impl = lambda v: eval_pack_ref(pack, fid, v, extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = eval_pack_slope(pack, fid, x, extrapolate=extrapolate)
        return y, slope * dx

    return f


def make_attn_exp_fn(pack: TablePack, *, use_pallas: bool = True):
    """TableFlash exponent: ``exp(z)`` for z <= 0 served from ``exp_neg``.

    The closure flash attention threads as ``exp_fn`` (see
    ``models.attention._flash_inner``).  Both running-softmax arguments are
    non-positive by construction, so the member's [lo, 0] domain covers them
    with an UNDERFLOW-TO-ZERO tail below lo: exp(z) < exp(lo) ~ 1.1e-7 there,
    and returning exactly 0.0 matches f32 ``jnp.exp``'s own underflow for the
    hugely-negative masked-key arguments — masked, empty, and pad slots carry
    weight 0 in both the exact and the table path (a clamp-at-lo tail would
    leak exp(lo) weight per masked slot, dominating E_a at decode's
    ring-buffer occupancy).  The address math still clamps before the
    selector; the zero select is on the raw z.  Fused inside the Pallas
    kernel, explicit on the jnp oracle path — bit-identical under jit.
    Tangent is the table slope, zeroed outside [lo, 0) like every
    non-extrapolating member (the zero tail is constant), so gradients
    through the scan stay finite.  Error contract:
    :mod:`repro.core.attn_error`.
    """
    fid = pack.fn_id("exp_neg")
    lo = float(pack.boundaries[fid, 0])
    if use_pallas:
        from repro.kernels.table_pack_lookup import tableflash_exp_pallas

        fwd_impl = lambda v: tableflash_exp_pallas(pack, v)
    else:
        fwd_impl = lambda v: jnp.where(
            v < lo, 0.0, eval_pack_ref(pack, fid, jnp.maximum(v, lo)))

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        y = fwd_impl(x)
        slope = eval_pack_slope(pack, fid, x)
        return y, slope * dx

    return f


# --------------------------------------------------------------------------------------
# PolyPack — planner-designed degree-d coefficient packs, Horner-evaluated on read.
# --------------------------------------------------------------------------------------


class PolyTablePack(NamedTuple):
    """Device-ready polynomial multi-function pack.

    Member ``fid`` stores ``degree + 1`` coefficient codes per cell in one of
    THREE width-group vectors — ``codes8``/``codes16`` (integer codes) or
    ``codes32`` (the f32 members' raw coefficients, carried through the same
    dequant FMA with ``zero = ramp = 0, scale = 1`` so it is a bit-exact
    identity).  The per-sub-interval dequant params are lane-padded to
    ``max_degree + 1`` lanes for every member: a padded lane dequantizes to
    exactly 0.0 and a leading zero flows through Horner as ``0*t + c = c``,
    so ONE dequant + Horner op sequence serves mixed-degree, mixed-width
    packs (see :class:`repro.core.packing.PolyPackLayout`).
    """

    names: Tuple[str, ...]  # static: member function names (fn_id order)
    n_intervals: Tuple[int, ...]  # static: sub-interval count per member
    degrees: Tuple[int, ...]  # static: interpolation degree per member
    entry_bits: Tuple[int, ...]  # static: 8 | 16 | 32 → which codes vector
    max_degree: int  # static: widest member degree (lane padding target)
    boundaries: jax.Array  # (sum n_f+1,) f32 flat rows
    inv_delta: jax.Array  # (sum n_f,) f32
    base: jax.Array  # (sum n_f,) f32 — GLOBAL index into the width-group codes
    seg_count: jax.Array  # (sum n_f,) f32
    zero: jax.Array  # (sum n_f * (max_degree+1),) f32 lane-padded
    ramp: jax.Array  # (sum n_f * (max_degree+1),) f32 lane-padded
    scale: jax.Array  # (sum n_f * (max_degree+1),) f32 lane-padded
    codes8: jax.Array  # (max(M8,1),) int8
    codes16: jax.Array  # (max(M16,1),) int16
    codes32: jax.Array  # (max(M32,1),) f32 — raw coefficients

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def max_lanes(self) -> int:
        return self.max_degree + 1

    @property
    def footprint(self) -> int:
        """Stored codes — excludes the 1-entry dummy of an unused width group,
        so it agrees with :class:`PolyPackLayout`'s accounting."""
        m8 = self.codes8.shape[0] if 8 in self.entry_bits else 0
        m16 = self.codes16.shape[0] if 16 in self.entry_bits else 0
        m32 = self.codes32.shape[0] if 32 in self.entry_bits else 0
        return int(m8 + m16 + m32)

    @property
    def footprint_bytes(self) -> int:
        m8 = self.codes8.shape[0] if 8 in self.entry_bits else 0
        m16 = self.codes16.shape[0] if 16 in self.entry_bits else 0
        m32 = self.codes32.shape[0] if 32 in self.entry_bits else 0
        return int(m8 + 2 * m16 + 4 * m32)

    def fn_id(self, name: str) -> int:
        return _member_id(self.names, name)

    def member_id(self, fn) -> int:
        """Name or integer fn_id -> validated index (KeyError otherwise)."""
        return _member_id(self.names, fn)

    def bounds_offset(self, fid: int) -> int:
        return sum(n + 1 for n in self.n_intervals[:fid])

    def lane_offset(self, fid: int) -> int:
        return sum(self.n_intervals[:fid])

    def codes_for(self, fid: int) -> jax.Array:
        bits = self.entry_bits[fid]
        return (self.codes8 if bits == 8
                else self.codes16 if bits == 16 else self.codes32)

    def routing_scalars(self) -> Tuple[np.ndarray, ...]:
        """Prefetched scalar operands for dynamic fn_id dispatch — the quant
        tuple plus the per-member coefficient stride ``degree + 1``:
        ``(n_arr, bounds_offsets, lane_offsets, entry_bits, strides)``."""
        F = self.n_functions
        return (np.asarray(self.n_intervals, dtype=np.int32),
                np.asarray([self.bounds_offset(f) for f in range(F)], np.int32),
                np.asarray([self.lane_offset(f) for f in range(F)], np.int32),
                np.asarray(self.entry_bits, dtype=np.int32),
                np.asarray([d + 1 for d in self.degrees], dtype=np.int32))


def from_poly_layout(layout: PolyPackLayout) -> PolyTablePack:
    if max(len(layout.codes8), len(layout.codes16),
           len(layout.codes32)) >= (1 << 24):
        raise ValueError("pack footprint exceeds f32 exact-integer range")

    def codes_arr(codes: np.ndarray, dtype) -> jax.Array:
        if len(codes) == 0:  # keep a 1-entry dummy so the operand stays valid
            return jnp.zeros((1,), dtype=dtype)
        return jnp.asarray(codes, dtype=dtype)

    f32 = lambda a: jnp.asarray(np.asarray(a, dtype=np.float64),
                                dtype=jnp.float32)
    return PolyTablePack(
        names=layout.names,
        n_intervals=layout.n_intervals,
        degrees=layout.degrees,
        entry_bits=layout.entry_bits,
        max_degree=layout.max_degree,
        boundaries=f32(layout.boundaries),
        inv_delta=f32(layout.inv_delta),
        base=f32(layout.base),
        seg_count=f32(layout.seg_count),
        zero=f32(layout.zero),
        ramp=f32(layout.ramp),
        scale=f32(layout.scale),
        codes8=codes_arr(layout.codes8, jnp.int8),
        codes16=codes_arr(layout.codes16, jnp.int16),
        codes32=codes_arr(layout.codes32, jnp.float32),
    )


def build_poly_pack(
    names: Sequence[str],
    e_a: float,
    *,
    budget_bytes: Optional[int] = None,
    rho: float = 0.9,
    dtype: str = "auto",
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    intervals: Optional[dict] = None,
) -> PolyTablePack:
    """Planner-driven pack: ``repro.core.design.plan`` picks one (degree,
    dtype) candidate per function — cheapest when ``budget_bytes=None``,
    preferred-then-downgraded to fit a byte budget otherwise — and the chosen
    members fuse into one device artifact.  ``dtype`` narrows the planner's
    menu ('auto' keeps f32/int16/int8 all open); ``rho`` splits e_a between
    interpolation and code rounding for the integer candidates."""
    from repro.core import design

    dtypes = design.POLY_DTYPES if dtype == "auto" else (dtype,)
    p = design.plan(list(names), e_a, budget_bytes, dtypes=dtypes,
                    algorithm=algorithm, omega=omega, rho=rho,
                    intervals=intervals)
    return from_poly_layout(poly_pack_layout(list(p.members)))


def _poly_select(pack: PolyTablePack, fid: int, xf: jax.Array):
    """Selector + gathers against member ``fid``'s ragged lane segment; the
    dequant planes come back with a trailing ``max_degree + 1`` lane axis."""
    bo, lo = pack.bounds_offset(fid), pack.lane_offset(fid)
    n = pack.n_intervals[fid]
    lmax = pack.max_lanes
    brow = pack.boundaries[bo : bo + n + 1]
    j = select_interval(brow, n, xf)
    p = jnp.take(brow, j, axis=0)
    invd = jnp.take(pack.inv_delta[lo : lo + n], j, axis=0)
    base = jnp.take(pack.base[lo : lo + n], j, axis=0)
    segs = jnp.take(pack.seg_count[lo : lo + n], j, axis=0)
    lanes = slice(lo * lmax, (lo + n) * lmax)
    zero = jnp.take(pack.zero[lanes].reshape(n, lmax), j, axis=0)
    ramp = jnp.take(pack.ramp[lanes].reshape(n, lmax), j, axis=0)
    scale = jnp.take(pack.scale[lanes].reshape(n, lmax), j, axis=0)
    return p, invd, base, segs, zero, ramp, scale


def _poly_coeffs(pack: PolyTablePack, fid: int, base, i, zero, ramp, scale):
    """Gather + dequantize the cell's ``degree + 1`` monomial coefficients.

    Code of cell ``i``, lane ``l`` lives at ``base + i*(degree+1) + l`` in the
    member's width group; the dequant FMA ``(zero + ramp*i) + scale*q`` is the
    quant-pack sequence per lane (identity for f32 members).
    """
    codes = pack.codes_for(fid)
    stride = float(pack.degrees[fid] + 1)
    cs = []
    for l in range(pack.degrees[fid] + 1):
        a = (base + i * stride + float(l)).astype(jnp.int32)
        q = jnp.take(codes, a, axis=0).astype(jnp.float32)
        cs.append((zero[..., l] + ramp[..., l] * i) + scale[..., l] * q)
    return cs


def poly_horner(cs, t):
    """p(t) with monomial coefficients ``cs[k]`` (constant term first)."""
    y = cs[-1]
    for c in reversed(cs[:-1]):
        y = y * t + c
    return y


def poly_horner_d1(cs, t):
    """p'(t) in the derivative Horner form the kernels mirror."""
    if len(cs) == 1:
        return jnp.zeros_like(t)
    g = cs[-1] * float(len(cs) - 1)
    for k in range(len(cs) - 2, 0, -1):
        g = g * t + cs[k] * float(k)
    return g


def eval_poly_pack_ref(pack: PolyTablePack, fn, x: jax.Array, *,
                       extrapolate: bool = False) -> jax.Array:
    """Pure-jnp dequantize + Horner oracle — bit-identical to the Pallas
    kernel.  ``extrapolate=True`` continues past the cell grid along the
    tangent at the clamped coordinate: ``y = p(tc) + p'(tc) * (t - tc)``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs, zero, ramp, scale = _poly_select(pack, fid, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    cs = _poly_coeffs(pack, fid, base, i, zero, ramp, scale)
    t = u - i
    tc = jnp.clip(t, 0.0, 1.0)
    y = poly_horner(cs, tc)
    if extrapolate:
        y = y + poly_horner_d1(cs, tc) * (t - tc)
    return y.astype(dtype)


def eval_poly_pack_slope(pack: PolyTablePack, fn, x: jax.Array, *,
                         extrapolate: bool = False) -> jax.Array:
    """d/dx of the polynomial surrogate: ``p'(tc) / delta`` (the tangent the
    extrapolating value path continues along), masked outside the domain when
    not extrapolating."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs, zero, ramp, scale = _poly_select(pack, fid, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    cs = _poly_coeffs(pack, fid, base, i, zero, ramp, scale)
    tc = jnp.clip(u - i, 0.0, 1.0)
    slope = poly_horner_d1(cs, tc) * invd
    if not extrapolate:
        bo = pack.bounds_offset(fid)
        n = pack.n_intervals[fid]
        inside = ((xf >= pack.boundaries[bo]) &
                  (xf < pack.boundaries[bo + n]))
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


def make_poly_pack_fn(
    pack: PolyTablePack,
    name: str,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Differentiable unary ``f(x)`` served from the polynomial pack.

    Mirrors :func:`make_quant_pack_fn`: Horner-slope tangent by default,
    ``exact_d1`` for the analytic derivative, ``use_pallas=True`` for the
    fused dequantize + Horner kernel (value + slope in one selector pass on
    the training path).
    """
    fid = pack.fn_id(name)
    if use_pallas:
        from repro.kernels.table_pack_lookup import (
            poly_pack_grad_pallas, poly_pack_lookup_pallas)

        fwd_impl = lambda v: poly_pack_lookup_pallas(
            pack, fid, v, extrapolate=extrapolate)
        fused_grad = lambda v: poly_pack_grad_pallas(
            pack, fid, v, extrapolate=extrapolate)
    else:
        fwd_impl = lambda v: eval_poly_pack_ref(pack, fid, v,
                                                extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = eval_poly_pack_slope(pack, fid, x, extrapolate=extrapolate)
        return y, slope * dx

    return f


# --------------------------------------------------------------------------------------
# ShardedPack — the pack's values vector partitioned over the 'model' mesh axis.
# --------------------------------------------------------------------------------------
#
# The packs above are REPLICATED: every core pins the whole values vector in
# VMEM.  Once the pack outgrows a core's budget, the values are instead
# partitioned at sub-interval granularity (core.packing.shard_pack_layout) and
# each shard answers ONLY the elements whose selected sub-interval it owns:
# every shard runs the full (replicated, small) comparator plane, gathers from
# its LOCAL slice with the rebased base, masks unowned elements to zero, and
# the shard contributions combine by summation — psum over the 'model' axis
# under shard_map, a plain sum over the stacked shard axis off-mesh.  Exactly
# one shard owns any selected sub-interval, so the sum adds one real value and
# S-1 zeros: the result is BIT-IDENTICAL to the replicated pack (x + 0.0 == x
# for every float x), which tests/test_sharded_pack.py asserts per function.


class ShardedTablePack(NamedTuple):
    """Device-ready sharded multi-function pack.

    ``values`` carries one PADDED slice per shard (stacked so the shard axis
    can be laid over the 'model' mesh axis); ``local_base``/``owned`` are the
    per-shard planes (rebased addresses + ownership mask); the selector
    metadata stays replicated.  See :class:`repro.core.packing.ShardedPackLayout`.
    """

    names: Tuple[str, ...]  # static: member function names (fn_id order)
    n_intervals: Tuple[int, ...]  # static: real sub-interval count per member
    n_shards: int  # static: width of the shard (mesh 'model') axis
    boundaries: jax.Array  # (F, n_max+1) f32, right-padded +inf  [replicated]
    inv_delta: jax.Array  # (F, n_max)   f32                      [replicated]
    seg_count: jax.Array  # (F, n_max)   f32                      [replicated]
    local_base: jax.Array  # (S, F, n_max) f32 — SHARD-LOCAL values index
    owned: jax.Array  # (S, F, n_max) f32 — 1.0 where shard s owns (f, j)
    values: jax.Array  # (S, m_max)   f32 — per-shard padded slices

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def n_max(self) -> int:
        return self.inv_delta.shape[1]

    @property
    def footprint_per_shard(self) -> int:
        """Padded per-shard entry count — the VMEM high-water every core pays."""
        return self.values.shape[1]

    def fn_id(self, name: str) -> int:
        return _member_id(self.names, name)

    def member_id(self, fn) -> int:
        """Name or integer fn_id -> validated index (KeyError otherwise)."""
        return _member_id(self.names, fn)

    def routing_scalars(self) -> Tuple[np.ndarray, ...]:
        """Prefetched scalar operands for dynamic fn_id dispatch (same contract
        as :meth:`TablePack.routing_scalars`)."""
        return (np.asarray(self.n_intervals, dtype=np.int32),)


def from_sharded_layout(slayout: ShardedPackLayout,
                        dtype=jnp.float32) -> ShardedTablePack:
    if slayout.max_shard_entries >= (1 << 24):
        raise ValueError("shard slice exceeds f32 exact-integer range")
    lay = slayout.layout
    S, m_max = slayout.n_shards, slayout.max_shard_entries
    vals = np.zeros((S, m_max), dtype=np.float64)
    for s in range(S):
        sv = slayout.shard_values(s)
        vals[s, : len(sv)] = sv
    lb = np.zeros((S,) + slayout.owner.shape, dtype=np.float64)
    own = np.zeros((S,) + slayout.owner.shape, dtype=np.float64)
    for s in range(S):
        mask = slayout.owner == s
        lb[s][mask] = slayout.local_base[mask]
        own[s][mask] = 1.0
    return ShardedTablePack(
        names=lay.names,
        n_intervals=lay.n_intervals,
        n_shards=S,
        boundaries=jnp.asarray(lay.boundaries, dtype=dtype),
        inv_delta=jnp.asarray(lay.inv_delta, dtype=dtype),
        seg_count=jnp.asarray(lay.seg_count.astype(np.float64), dtype=dtype),
        local_base=jnp.asarray(lb, dtype=dtype),
        owned=jnp.asarray(own, dtype=dtype),
        values=jnp.asarray(vals, dtype=dtype),
    )


def shard_pack(pack_or_specs, n_shards: int) -> ShardedTablePack:
    """Shard already-built TableSpecs (or a PackLayout) into a runtime pack."""
    layout = (pack_or_specs if isinstance(pack_or_specs, PackLayout)
              else pack_layout(list(pack_or_specs)))
    return from_sharded_layout(shard_pack_layout(layout, n_shards))


def build_sharded_pack(
    names: Sequence[str],
    e_a: float,
    n_shards: int,
    *,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    intervals: Optional[dict] = None,
) -> ShardedTablePack:
    """Design flow for every name, fused into one pack, sharded ``n_shards`` ways."""
    intervals = intervals or {}
    specs = []
    for name in names:
        lo, hi = intervals.get(name, (None, None))
        specs.append(cached_table(name, e_a, lo, hi, algorithm=algorithm,
                                  omega=omega))
    return shard_pack(specs, n_shards)


def shard_contrib_ref(values_s, lbase_row, own_row, brow, invd_row, segs_row,
                      n: int, xf: jax.Array, *, extrapolate: bool,
                      slope: bool = False) -> jax.Array:
    """ONE shard's masked contribution — the sharded-lookup contract.

    Runs the replicated comparator plane, gathers from the LOCAL values slice
    at the rebased address, and zeroes elements whose selected sub-interval
    this shard does not own.  The owner shard executes exactly the replicated
    pack's compare/gather/FMA sequence on the same f32 numbers (the slice
    holds the same entries, only re-addressed), so summing the S contributions
    reproduces ``eval_pack_ref``/``eval_pack_slope`` bit for bit.  Shared by
    the jnp oracle, the shard_map mesh body, and (as the reference for) the
    Pallas shard kernel.
    """
    j = select_interval(brow, n, xf)
    p = jnp.take(brow, j, axis=0)
    invd = jnp.take(invd_row, j, axis=0)
    base = jnp.take(lbase_row, j, axis=0)
    segs = jnp.take(segs_row, j, axis=0)
    own = jnp.take(own_row, j, axis=0)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    # clip: unowned elements may address past the local slice; they are masked
    y0 = jnp.take(values_s, a, axis=0, mode="clip")
    y1 = jnp.take(values_s, a + 1, axis=0, mode="clip")
    if slope:
        out = (y1 - y0) * invd
        if not extrapolate:
            inside = (xf >= brow[0]) & (xf < brow[n])
            out = out * inside.astype(jnp.float32)
    else:
        t = u - i
        if not extrapolate:
            t = jnp.clip(t, 0.0, 1.0)
        out = y0 + t * (y1 - y0)
    return jnp.where(own > 0, out, 0.0)


def _sharded_sum_ref(pack: ShardedTablePack, fid: int, xf: jax.Array,
                     extrapolate: bool, slope: bool) -> jax.Array:
    out = None
    for s in range(pack.n_shards):
        c = shard_contrib_ref(
            pack.values[s], pack.local_base[s, fid], pack.owned[s, fid],
            pack.boundaries[fid], pack.inv_delta[fid], pack.seg_count[fid],
            pack.n_intervals[fid], xf, extrapolate=extrapolate, slope=slope)
        out = c if out is None else out + c
    return out


def eval_sharded_ref(pack: ShardedTablePack, fn, x: jax.Array, *,
                     extrapolate: bool = False) -> jax.Array:
    """Pure-jnp sharded oracle (stacked shard axis, no mesh required) —
    bit-identical to the replicated ``eval_pack_ref``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    return _sharded_sum_ref(pack, fid, xf, extrapolate, slope=False).astype(dtype)


def eval_sharded_slope(pack: ShardedTablePack, fn, x: jax.Array, *,
                       extrapolate: bool = False) -> jax.Array:
    """d/dx of the sharded surrogate — bit-identical to ``eval_pack_slope``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    return _sharded_sum_ref(pack, fid, xf, extrapolate, slope=True).astype(dtype)


def _active_pack_mesh(pack: ShardedTablePack):
    """The bound mesh IF its 'model' axis matches the pack's shard count.

    ``use_sharding`` binds the mesh at trace time; when no binding is active
    (or the model axis width differs) the stacked-shard-axis path below is
    used instead — same math, same bits, no distribution.
    """
    from repro.parallel.sharding import current_mesh

    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] == pack.n_shards):
        return mesh
    return None


def eval_sharded_mesh(pack: ShardedTablePack, fn, x: jax.Array, mesh, *,
                      extrapolate: bool = False, use_pallas: bool = False,
                      slope: bool = False) -> jax.Array:
    """Sharded evaluation distributed over ``mesh``'s 'model' axis.

    Each device holds ONE shard's values slice + planes (lay the pack out with
    :func:`repro.parallel.sharding.sharded_pack_pspecs`); the shard_map body
    computes the local masked contribution and a psum over 'model' combines
    them.  psum adds one owner value and S-1 zeros, so the result is
    bit-identical to the replicated pack AND to the off-mesh stacked sum.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    n = pack.n_intervals[fid]

    def body(values, lbase, own, xloc):
        if use_pallas:
            from repro.kernels.table_pack_lookup import sharded_shard_contrib_pallas

            c = sharded_shard_contrib_pallas(
                pack.boundaries, pack.inv_delta, pack.seg_count,
                lbase[0], own[0], values[0], xloc,
                fn_id=fid, n_intervals=n, extrapolate=extrapolate, slope=slope)
        else:
            c = shard_contrib_ref(
                values[0], lbase[0, fid], own[0, fid], pack.boundaries[fid],
                pack.inv_delta[fid], pack.seg_count[fid], n, xloc,
                extrapolate=extrapolate, slope=slope)
        return jax.lax.psum(c, "model")

    rep = P(*(None,) * xf.ndim)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model"), rep),
        out_specs=rep,
        # pallas_call has no shard_map replication rule; the explicit psum
        # above makes the output replicated regardless
        check_rep=not use_pallas,
    )(pack.values, pack.local_base, pack.owned, xf)
    return out.astype(dtype)


def make_sharded_pack_fn(
    pack: ShardedTablePack,
    name: str,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Differentiable unary ``f(x)`` served from the SHARDED pack.

    Mirrors :func:`make_pack_fn`; the forward picks its execution at trace
    time: under an active ``use_sharding`` binding whose 'model' axis is
    ``pack.n_shards`` wide it runs shard_map + psum (each device holds one
    values slice), otherwise it sums the stacked shard contributions on one
    device.  Both are bit-identical to the replicated pack.
    """
    fid = pack.fn_id(name)

    def fwd_impl(v):
        mesh = _active_pack_mesh(pack)
        if mesh is not None:
            return eval_sharded_mesh(pack, fid, v, mesh,
                                     extrapolate=extrapolate,
                                     use_pallas=use_pallas)
        if use_pallas:
            from repro.kernels.table_pack_lookup import sharded_pack_lookup_pallas

            return sharded_pack_lookup_pallas(pack, fid, v,
                                              extrapolate=extrapolate)
        return eval_sharded_ref(pack, fid, v, extrapolate=extrapolate)

    def slope_impl(v):
        mesh = _active_pack_mesh(pack)
        if mesh is not None:
            return eval_sharded_mesh(pack, fid, v, mesh,
                                     extrapolate=extrapolate,
                                     use_pallas=use_pallas, slope=True)
        if use_pallas:
            from repro.kernels.table_pack_lookup import sharded_pack_slope_pallas

            return sharded_pack_slope_pallas(pack, fid, v,
                                             extrapolate=extrapolate)
        return eval_sharded_slope(pack, fid, v, extrapolate=extrapolate)

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif use_pallas and _active_pack_mesh(pack) is None:
            # off-mesh training path: fused (value, slope) in one selector pass
            from repro.kernels.table_pack_lookup import sharded_pack_grad_pallas

            y, slope = sharded_pack_grad_pallas(pack, fid, x,
                                                extrapolate=extrapolate)
        else:
            y = fwd_impl(x)
            slope = slope_impl(x)
        return y, slope * dx

    return f


# --------------------------------------------------------------------------------------
# RoutedPack — per-row DYNAMIC fn_id dispatch (one executable, mixed-function batches).
# --------------------------------------------------------------------------------------
#
# The pack kernels above specialize on a static fn_id: a batch mixing functions
# (MoE-style routed activations) needs one compiled executable per member.  The
# routed variant instead takes a per-row ``fn_ids`` vector as a RUNTIME operand
# — ``repro.kernels.routed_pack_lookup`` prefetches it as a scalar operand
# (PrefetchScalarGridSpec) and picks each row's metadata at dispatch time, so
# ONE executable serves every routing.  The oracles here define the contract:
# row i of the output is bit-identical to the static-fn_id dispatch of member
# fn_ids[i] (the where-select literally picks the static per-member values).


def resolve_fn_ids(pack, fn_ids, rows: int) -> jax.Array:
    """Normalize per-row routing ids to a clipped ``(rows,)`` int32 vector.

    Accepts a single name/int (broadcast to every row), a sequence of
    names/ints or a concrete array (each validated against the pack —
    ``KeyError`` on unknowns), or a TRACED int vector (e.g. a router output
    under jit).  Traced ids cannot be validated at trace time; they are
    clamped to the member range, matching the kernels' clamped metadata
    reads.
    """
    if isinstance(fn_ids, (str, int, np.integer)):
        ids = np.full((rows,), pack.member_id(fn_ids), dtype=np.int32)
    elif isinstance(fn_ids, jax.core.Tracer):
        ids = jnp.asarray(fn_ids, dtype=jnp.int32)
    else:  # concrete sequence/array (names or ints): validate every id
        seq = fn_ids if isinstance(fn_ids, (list, tuple)) else np.asarray(fn_ids)
        ids = np.asarray([pack.member_id(f) for f in seq], dtype=np.int32)
    if ids.shape != (rows,):
        raise ValueError(
            f"fn_ids shape {ids.shape} does not match the {rows} leading rows "
            f"of x (one function id per row)")
    return jnp.clip(jnp.asarray(ids, dtype=jnp.int32), 0, pack.n_functions - 1)


def routed_extr_flags(pack, extrapolate) -> np.ndarray:
    """Per-member edge-handling flags as the int32 runtime operand the routed
    kernels gather by fn_id: a single bool applies to every member, a sequence
    gives one flag per member (linear-asymptote members extrapolate, flat ones
    keep the hardware clamp)."""
    if isinstance(extrapolate, (bool, np.bool_, int)):
        flags = (bool(extrapolate),) * pack.n_functions
    else:
        flags = tuple(bool(e) for e in extrapolate)
        if len(flags) != pack.n_functions:
            raise ValueError(
                f"extrapolate needs one flag per member ({pack.n_functions}), "
                f"got {len(flags)}")
    return np.asarray(flags, dtype=np.int32)


def _routed_where(pack, fn_ids, x, member_eval, extrapolate):
    """Row-select over the static per-member evaluations (the routed oracle)."""
    ids = resolve_fn_ids(pack, fn_ids, x.shape[0])
    extr = routed_extr_flags(pack, extrapolate)
    sel = (x.shape[0],) + (1,) * (x.ndim - 1)
    y = None
    for f in range(pack.n_functions):
        yf = member_eval(f, bool(extr[f]))
        y = yf if y is None else jnp.where((ids == f).reshape(sel), yf, y)
    return y


def eval_routed_ref(pack: TablePack, fn_ids, x: jax.Array, *,
                    extrapolate=False) -> jax.Array:
    """Pure-jnp routed oracle: row i of ``x`` through member ``fn_ids[i]`` —
    bit-identical to the corresponding static dispatches."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_pack_ref(pack, f, x, extrapolate=e), extrapolate)


def eval_routed_slope(pack: TablePack, fn_ids, x: jax.Array, *,
                      extrapolate=False) -> jax.Array:
    """d/dx of the routed surrogate (per-row static table slopes)."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_pack_slope(pack, f, x, extrapolate=e), extrapolate)


def eval_routed_quant_ref(pack: QuantTablePack, fn_ids, x: jax.Array, *,
                          extrapolate=False) -> jax.Array:
    """Routed dequantize-on-read oracle over the quantized pack."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_quant_pack_ref(pack, f, x, extrapolate=e), extrapolate)


def eval_routed_quant_slope(pack: QuantTablePack, fn_ids, x: jax.Array, *,
                            extrapolate=False) -> jax.Array:
    """d/dx of the routed quantized surrogate."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_quant_pack_slope(pack, f, x, extrapolate=e),
        extrapolate)


def eval_routed_poly_ref(pack: PolyTablePack, fn_ids, x: jax.Array, *,
                         extrapolate=False) -> jax.Array:
    """Routed dequantize + Horner oracle over the polynomial pack."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_poly_pack_ref(pack, f, x, extrapolate=e), extrapolate)


def eval_routed_poly_slope(pack: PolyTablePack, fn_ids, x: jax.Array, *,
                           extrapolate=False) -> jax.Array:
    """d/dx of the routed polynomial surrogate."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_poly_pack_slope(pack, f, x, extrapolate=e),
        extrapolate)


def eval_routed_sharded_ref(pack: ShardedTablePack, fn_ids, x: jax.Array, *,
                            extrapolate=False) -> jax.Array:
    """Routed oracle over the SHARDED pack: row i through member ``fn_ids[i]``
    with each member's value summed from its shard contributions."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_sharded_ref(pack, f, x, extrapolate=e), extrapolate)


def eval_routed_sharded_slope(pack: ShardedTablePack, fn_ids, x: jax.Array, *,
                              extrapolate=False) -> jax.Array:
    """d/dx of the routed sharded surrogate."""
    return _routed_where(
        pack, fn_ids, x,
        lambda f, e: eval_sharded_slope(pack, f, x, extrapolate=e), extrapolate)


def make_routed_fn(
    pack,
    fn_ids,
    *,
    use_pallas: bool = True,
    extrapolate=False,
):
    """Differentiable per-row routed ``f(x)``: row i of ``x`` (leading axis)
    is served by member ``fn_ids[i]`` of the pack — f32 (:class:`TablePack`)
    or quantized (:class:`QuantTablePack`) — from ONE compiled executable.

    ``fn_ids`` may be names/ints (validated here) or a traced int vector (an
    MoE router output): the ids are a runtime operand of the scalar-prefetch
    kernels, so re-routing never recompiles.  ``extrapolate`` is one flag or a
    per-member sequence (mixed edge semantics in a single call).  The tangent
    is the per-row table slope (what the hardware computes), fused with the
    value pass in the Pallas path.
    """
    quant = isinstance(pack, QuantTablePack)
    poly = isinstance(pack, PolyTablePack)
    sharded = isinstance(pack, ShardedTablePack)
    if use_pallas:
        from repro.kernels.routed_pack_lookup import (
            routed_pack_grad_pallas, routed_pack_lookup_pallas,
            routed_poly_pack_grad_pallas, routed_poly_pack_lookup_pallas,
            routed_quant_pack_grad_pallas, routed_quant_pack_lookup_pallas,
            sharded_routed_pack_grad_pallas, sharded_routed_pack_lookup_pallas)

        if sharded:
            lookup, gradk = (sharded_routed_pack_lookup_pallas,
                             sharded_routed_pack_grad_pallas)
        elif poly:
            lookup, gradk = (routed_poly_pack_lookup_pallas,
                             routed_poly_pack_grad_pallas)
        elif quant:
            lookup, gradk = (routed_quant_pack_lookup_pallas,
                             routed_quant_pack_grad_pallas)
        else:
            lookup, gradk = routed_pack_lookup_pallas, routed_pack_grad_pallas
        fwd_impl = lambda v: lookup(pack, fn_ids, v, extrapolate=extrapolate)
        fused_grad = lambda v: gradk(pack, fn_ids, v, extrapolate=extrapolate)
    else:
        if sharded:
            ref, slope_ref = eval_routed_sharded_ref, eval_routed_sharded_slope
        elif poly:
            ref, slope_ref = eval_routed_poly_ref, eval_routed_poly_slope
        elif quant:
            ref, slope_ref = eval_routed_quant_ref, eval_routed_quant_slope
        else:
            ref, slope_ref = eval_routed_ref, eval_routed_slope
        fwd_impl = lambda v: ref(pack, fn_ids, v, extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = slope_ref(pack, fn_ids, x, extrapolate=extrapolate)
        return y, slope * dx

    return f


def make_routed_unary_fn(
    pack,
    name,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Shape-agnostic unary ``f(x)`` served through the ROUTED dispatch path
    with uniform fn_ids — what ``ApproxConfig(mode="routed_pack").unary``
    builds.  Unlike :func:`make_pack_fn`, the member identity is a runtime
    operand: every member's unary shares one compiled executable per input
    shape.  The jnp fallback (``use_pallas=False``) evaluates the static
    oracle — bit-identical to the routed kernel by the dispatch contract.
    """
    quant = isinstance(pack, QuantTablePack)
    poly = isinstance(pack, PolyTablePack)
    fid = pack.member_id(name)
    ids = jnp.full((1,), fid, dtype=jnp.int32)
    if use_pallas:
        from repro.kernels.routed_pack_lookup import (
            routed_pack_grad_pallas, routed_pack_lookup_pallas,
            routed_poly_pack_grad_pallas, routed_poly_pack_lookup_pallas,
            routed_quant_pack_grad_pallas, routed_quant_pack_lookup_pallas)

        if poly:
            lookup, gradk = (routed_poly_pack_lookup_pallas,
                             routed_poly_pack_grad_pallas)
        elif quant:
            lookup, gradk = (routed_quant_pack_lookup_pallas,
                             routed_quant_pack_grad_pallas)
        else:
            lookup, gradk = routed_pack_lookup_pallas, routed_pack_grad_pallas
        fwd_impl = lambda v: lookup(
            pack, ids, v.reshape(1, -1), extrapolate=extrapolate
        ).reshape(v.shape)
        fused_grad = lambda v: tuple(
            r.reshape(v.shape) for r in gradk(
                pack, ids, v.reshape(1, -1), extrapolate=extrapolate))
    else:
        if poly:
            ref, slope_ref = eval_poly_pack_ref, eval_poly_pack_slope
        elif quant:
            ref, slope_ref = eval_quant_pack_ref, eval_quant_pack_slope
        else:
            ref, slope_ref = eval_pack_ref, eval_pack_slope
        fwd_impl = lambda v: ref(pack, fid, v, extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = slope_ref(pack, fid, x, extrapolate=extrapolate)
        return y, slope * dx

    return f


# --------------------------------------------------------------------------------------
# Telemetry probes (repro.obs device telemetry; see ApproxConfig._maybe_instrument_unary)
# --------------------------------------------------------------------------------------


def member_domain(pack, fn):
    """Member ``fn``'s table domain ``[lo, hi)`` as two f32 device scalars.

    Works across pack families: row-padded boundaries (TablePack /
    ShardedTablePack, ``(F, n_max+1)``) index by ``fid``; flat ragged
    boundaries (QuantTablePack / PolyTablePack) index via the member's static
    ``bounds_offset``.  Inputs outside ``[lo, hi)`` hit the hardware clamp (or
    the linear edge extrapolation for ``_EXTRAPOLATE`` activations) — the
    out-of-domain event the telemetry layer counts.
    """
    fid = _resolve(pack, fn)
    n = pack.n_intervals[fid]
    if pack.boundaries.ndim == 2:
        return pack.boundaries[fid, 0], pack.boundaries[fid, n]
    bo = pack.bounds_offset(fid)
    return pack.boundaries[bo], pack.boundaries[bo + n]


def quant_saturation_counts(pack: QuantTablePack, fn, x: jax.Array):
    """(saturated, total) endpoint-code gathers member ``fn`` performs on ``x``.

    A gathered code at the signed extreme of its width (|c| >= 127 for int8,
    >= 32767 for int16) means the per-sub-interval affine quantizer clipped
    that entry — rounding error there can exceed the planner's budget, so the
    saturation RATE (saturated / total) is the quant health signal the
    telemetry layer reports per function.  Reuses the production selector
    (``_quant_select``), so the counted addresses are exactly the ones the
    dequantize-on-read evaluators gather; each lookup touches the two chord
    endpoints, hence ``total == 2 * x.size``.
    """
    fid = _resolve(pack, fn)
    xf = jnp.asarray(x).astype(jnp.float32)
    p, invd, base, segs, _, _, _ = _quant_select(pack, fid, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    codes = pack.codes_for(fid)
    qmax = 127 if pack.entry_bits[fid] == 8 else 32767
    c0 = jnp.abs(jnp.take(codes, a, axis=0).astype(jnp.int32))
    c1 = jnp.abs(jnp.take(codes, a + 1, axis=0).astype(jnp.int32))
    sat = jnp.sum((c0 >= qmax).astype(jnp.int32)) + \
        jnp.sum((c1 >= qmax).astype(jnp.int32))
    return sat, 2 * xf.size
