"""TablePack — every table a model needs, fused into ONE device artifact.

The paper keeps each function's table resident in BRAM next to its consumer
(Sec. 7.2); a network, however, evaluates a *set* of nonlinearities (gelu for
the MLP, sigmoid/tanh for gates, exp for softmax...), and shipping one table +
one kernel dispatch per function multiplies both the VMEM residency and the
dispatch overhead by F.  A :class:`TablePack` concatenates all range values
into a single ``values`` vector and stores selector metadata as (F, n_max)
padded planes (see :class:`repro.core.packing.PackLayout`), so

  * ONE artifact stays VMEM-resident for the whole network (BRAM instantiation
    lifted to the function-set level), and
  * ONE fused Pallas kernel — ``repro.kernels.table_pack_lookup`` — serves any
    member function via a static ``fn_id`` row index.

``eval_pack_ref`` is the pure-jnp oracle; it reproduces the per-table
``eval_table_ref`` BIT FOR BIT (same compare/gather/FMA sequence on the same
f32 values; the pack only rebases the BRAM addresses), which the parity tests
assert for every registered function.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flow import cached_table
from repro.core.packing import (PackLayout, QuantPackLayout, pack_layout,
                                quant_pack_layout)
from repro.core.quantize import plan_quant_member
from repro.core.table import TableSpec

from .jax_table import select_interval


class TablePack(NamedTuple):
    """Device-ready multi-function table artifact (all array leaves jnp, f32)."""

    names: Tuple[str, ...]  # static: member function names (fn_id order)
    n_intervals: Tuple[int, ...]  # static: real sub-interval count per member
    boundaries: jax.Array  # (F, n_max+1) f32, right-padded +inf
    inv_delta: jax.Array  # (F, n_max)   f32
    delta: jax.Array  # (F, n_max)   f32
    base: jax.Array  # (F, n_max)   f32 — GLOBAL packed-values index (exact < 2^24)
    seg_count: jax.Array  # (F, n_max)   f32
    values: jax.Array  # (M,)         f32 — all member tables, concatenated

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def n_max(self) -> int:
        return self.inv_delta.shape[1]

    @property
    def footprint(self) -> int:
        return self.values.shape[0]

    def fn_id(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(f"function {name!r} not in pack {self.names}") from None


def from_layout(layout: PackLayout, dtype=jnp.float32) -> TablePack:
    if layout.footprint >= (1 << 24):
        raise ValueError("pack footprint exceeds f32 exact-integer range")
    return TablePack(
        names=layout.names,
        n_intervals=layout.n_intervals,
        boundaries=jnp.asarray(layout.boundaries, dtype=dtype),
        inv_delta=jnp.asarray(layout.inv_delta, dtype=dtype),
        delta=jnp.asarray(layout.delta, dtype=dtype),
        base=jnp.asarray(layout.base.astype(np.float64), dtype=dtype),
        seg_count=jnp.asarray(layout.seg_count.astype(np.float64), dtype=dtype),
        values=jnp.asarray(layout.values, dtype=dtype),
    )


def pack_specs(specs: Sequence[TableSpec]) -> TablePack:
    """Pack already-built TableSpecs (order defines fn_id)."""
    return from_layout(pack_layout(specs))


def build_pack(
    names: Sequence[str],
    e_a: float,
    *,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    intervals: Optional[dict] = None,
) -> TablePack:
    """Run the design flow for every name and fuse the artifacts into one pack."""
    intervals = intervals or {}
    specs = []
    for name in names:
        lo, hi = intervals.get(name, (None, None))
        specs.append(cached_table(name, e_a, lo, hi, algorithm=algorithm,
                                  omega=omega))
    return pack_specs(specs)


def _resolve(pack: TablePack, fn) -> int:
    return pack.fn_id(fn) if isinstance(fn, str) else int(fn)


def _select_pack_params(pack: TablePack, fid: int, xf: jax.Array):
    """One selector + four gathers against function ``fid``'s metadata row."""
    brow = pack.boundaries[fid]
    j = select_interval(brow, pack.n_intervals[fid], xf)
    p = jnp.take(brow, j, axis=0)
    invd = jnp.take(pack.inv_delta[fid], j, axis=0)
    base = jnp.take(pack.base[fid], j, axis=0)
    segs = jnp.take(pack.seg_count[fid], j, axis=0)
    return p, invd, base, segs


def eval_pack_ref(pack: TablePack, fn, x: jax.Array, *,
                  extrapolate: bool = False) -> jax.Array:
    """Pure-jnp pack evaluation — bit-identical to per-table ``eval_table_ref``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_pack_params(pack, fid, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(pack.values, a, axis=0)
    y1 = jnp.take(pack.values, a + 1, axis=0)
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    return (y0 + t * (y1 - y0)).astype(dtype)


def eval_pack_slope(pack: TablePack, fn, x: jax.Array, *,
                    extrapolate: bool = False) -> jax.Array:
    """d/dx of the pack surrogate — bit-identical to ``eval_table_slope``."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_pack_params(pack, fid, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(pack.values, a, axis=0)
    y1 = jnp.take(pack.values, a + 1, axis=0)
    slope = (y1 - y0) * invd
    if not extrapolate:
        n = pack.n_intervals[fid]
        inside = (xf >= pack.boundaries[fid, 0]) & (xf < pack.boundaries[fid, n])
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


# --------------------------------------------------------------------------------------
# QuantPack — the pack with int8/int16 entry codes, dequantized on read.
# --------------------------------------------------------------------------------------


class QuantTablePack(NamedTuple):
    """Device-ready quantized multi-function pack.

    Entries live as int8/int16 codes in two width-group vectors; the selector
    metadata plus per-sub-interval dequant params (scale, zero, ramp) are flat
    RAGGED f32 lanes — member ``fid``'s segment starts at a STATIC offset
    derived from the static ``n_intervals`` tuple, so no (F, n_max) padding is
    paid (see :class:`repro.core.packing.QuantPackLayout`).  Dequantize-on-read
    is one extra FMA per gathered endpoint: ``v = (zero + ramp*i) + scale*q``.
    """

    names: Tuple[str, ...]  # static: member function names (fn_id order)
    n_intervals: Tuple[int, ...]  # static: sub-interval count per member
    entry_bits: Tuple[int, ...]  # static: 8 | 16 → which codes vector
    rho: Tuple[float, ...]  # static: interpolation share of e_a per member
    boundaries: jax.Array  # (sum n_f+1,) f32 flat rows
    inv_delta: jax.Array  # (sum n_f,) f32
    base: jax.Array  # (sum n_f,) f32 — GLOBAL index into the width-group codes
    seg_count: jax.Array  # (sum n_f,) f32
    scale: jax.Array  # (sum n_f,) f32
    zero: jax.Array  # (sum n_f,) f32
    ramp: jax.Array  # (sum n_f,) f32
    codes8: jax.Array  # (max(M8,1),) int8
    codes16: jax.Array  # (max(M16,1),) int16

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def footprint(self) -> int:
        """Stored entries — excludes the 1-entry dummy of an unused width group,
        so it agrees with :class:`QuantPackLayout`'s accounting."""
        m8 = self.codes8.shape[0] if 8 in self.entry_bits else 0
        m16 = self.codes16.shape[0] if 16 in self.entry_bits else 0
        return int(m8 + m16)

    @property
    def footprint_bytes(self) -> int:
        m8 = self.codes8.shape[0] if 8 in self.entry_bits else 0
        m16 = self.codes16.shape[0] if 16 in self.entry_bits else 0
        return int(m8 + 2 * m16)

    def fn_id(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(f"function {name!r} not in pack {self.names}") from None

    def bounds_offset(self, fid: int) -> int:
        return sum(n + 1 for n in self.n_intervals[:fid])

    def lane_offset(self, fid: int) -> int:
        return sum(self.n_intervals[:fid])

    def codes_for(self, fid: int) -> jax.Array:
        return self.codes8 if self.entry_bits[fid] == 8 else self.codes16


def from_quant_layout(layout: QuantPackLayout) -> QuantTablePack:
    if max(len(layout.codes8), len(layout.codes16)) >= (1 << 24):
        raise ValueError("pack footprint exceeds f32 exact-integer range")

    def codes_arr(codes: np.ndarray, dtype) -> jax.Array:
        if len(codes) == 0:  # keep a 1-entry dummy so the operand stays valid
            return jnp.zeros((1,), dtype=dtype)
        return jnp.asarray(codes, dtype=dtype)

    f32 = lambda a: jnp.asarray(np.asarray(a, dtype=np.float64),
                                dtype=jnp.float32)
    return QuantTablePack(
        names=layout.names,
        n_intervals=layout.n_intervals,
        entry_bits=layout.entry_bits,
        rho=tuple(m.rho for m in layout.members),
        boundaries=f32(layout.boundaries),
        inv_delta=f32(layout.inv_delta),
        base=f32(layout.base),
        seg_count=f32(layout.seg_count),
        scale=f32(layout.scale),
        zero=f32(layout.zero),
        ramp=f32(layout.ramp),
        codes8=codes_arr(layout.codes8, jnp.int8),
        codes16=codes_arr(layout.codes16, jnp.int16),
    )


def build_quant_pack(
    names: Sequence[str],
    e_a: float,
    *,
    rho: float = 0.9,
    dtype: str = "auto",
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    intervals: Optional[dict] = None,
) -> QuantTablePack:
    """Error-budgeted quantized pack: interpolation gets ``rho * e_a``, code
    rounding the rest; int8 vs int16 is chosen per member (``dtype='auto'``)."""
    intervals = intervals or {}
    members = []
    for name in names:
        lo, hi = intervals.get(name, (None, None))
        members.append(plan_quant_member(
            name, e_a, lo, hi, algorithm=algorithm, omega=omega,
            rho=rho, dtype=dtype))
    return from_quant_layout(quant_pack_layout(members))


def _quant_select(pack: QuantTablePack, fid: int, xf: jax.Array):
    """Selector + seven gathers against member ``fid``'s ragged lane segment."""
    bo, lo = pack.bounds_offset(fid), pack.lane_offset(fid)
    n = pack.n_intervals[fid]
    brow = pack.boundaries[bo : bo + n + 1]
    j = select_interval(brow, n, xf)
    p = jnp.take(brow, j, axis=0)
    invd = jnp.take(pack.inv_delta[lo : lo + n], j, axis=0)
    base = jnp.take(pack.base[lo : lo + n], j, axis=0)
    segs = jnp.take(pack.seg_count[lo : lo + n], j, axis=0)
    scale = jnp.take(pack.scale[lo : lo + n], j, axis=0)
    zero = jnp.take(pack.zero[lo : lo + n], j, axis=0)
    ramp = jnp.take(pack.ramp[lo : lo + n], j, axis=0)
    return p, invd, base, segs, scale, zero, ramp


def eval_quant_pack_ref(pack: QuantTablePack, fn, x: jax.Array, *,
                        extrapolate: bool = False) -> jax.Array:
    """Pure-jnp dequantize-on-read oracle — bit-identical to the Pallas kernel."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs, scale, zero, ramp = _quant_select(pack, fid, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    codes = pack.codes_for(fid)
    c0 = jnp.take(codes, a, axis=0).astype(jnp.float32)
    c1 = jnp.take(codes, a + 1, axis=0).astype(jnp.float32)
    r = zero + ramp * i  # the chord ramp at entry i
    y0 = r + scale * c0
    y1 = (r + ramp) + scale * c1
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    return (y0 + t * (y1 - y0)).astype(dtype)


def eval_quant_pack_slope(pack: QuantTablePack, fn, x: jax.Array, *,
                          extrapolate: bool = False) -> jax.Array:
    """d/dx of the quantized surrogate: (ramp + scale * (c1 - c0)) / delta."""
    fid = _resolve(pack, fn)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs, scale, zero, ramp = _quant_select(pack, fid, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    codes = pack.codes_for(fid)
    c0 = jnp.take(codes, a, axis=0).astype(jnp.float32)
    c1 = jnp.take(codes, a + 1, axis=0).astype(jnp.float32)
    slope = (ramp + scale * (c1 - c0)) * invd
    if not extrapolate:
        bo = pack.bounds_offset(fid)
        n = pack.n_intervals[fid]
        inside = ((xf >= pack.boundaries[bo]) &
                  (xf < pack.boundaries[bo + n]))
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


def make_quant_pack_fn(
    pack: QuantTablePack,
    name: str,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Differentiable unary ``f(x)`` served from the quantized pack.

    Mirrors :func:`make_pack_fn`: quantized-table-slope tangent by default,
    ``exact_d1`` for the analytic derivative, ``use_pallas=True`` for the
    fused dequantize-on-read kernel (value + slope in one selector pass on the
    training path).
    """
    fid = pack.fn_id(name)
    if use_pallas:
        from repro.kernels.table_pack_lookup import (
            quant_pack_grad_pallas, quant_pack_lookup_pallas)

        fwd_impl = lambda v: quant_pack_lookup_pallas(
            pack, fid, v, extrapolate=extrapolate)
        fused_grad = lambda v: quant_pack_grad_pallas(
            pack, fid, v, extrapolate=extrapolate)
    else:
        fwd_impl = lambda v: eval_quant_pack_ref(pack, fid, v,
                                                 extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = eval_quant_pack_slope(pack, fid, x, extrapolate=extrapolate)
        return y, slope * dx

    return f


def make_pack_fn(
    pack: TablePack,
    name: str,
    *,
    use_pallas: bool = True,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Differentiable unary ``f(x)`` evaluated through the shared pack.

    Mirrors ``repro.approx.make_table_fn``: table-slope tangent by default
    (what the hardware computes), ``exact_d1`` for the analytic derivative.
    ``use_pallas=True`` routes through the fused pack kernel (one selector pass
    yields value AND slope on the training path).
    """
    fid = pack.fn_id(name)
    if use_pallas:
        from repro.kernels.table_pack_lookup import (
            table_pack_grad_pallas, table_pack_lookup_pallas)

        fwd_impl = lambda v: table_pack_lookup_pallas(
            pack, fid, v, extrapolate=extrapolate)
        fused_grad = lambda v: table_pack_grad_pallas(
            pack, fid, v, extrapolate=extrapolate)
    else:
        fwd_impl = lambda v: eval_pack_ref(pack, fid, v, extrapolate=extrapolate)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = eval_pack_slope(pack, fid, x, extrapolate=extrapolate)
        return y, slope * dx

    return f
