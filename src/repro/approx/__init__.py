"""repro.approx — JAX runtime of the paper's table-based function approximation."""

from .activations import DEFAULT_PACK_FUNCTIONS, EXACT, ApproxConfig, get_exact
from .jax_table import JaxTable, eval_table_ref, eval_table_slope, from_spec, make_table_fn
from .table_pack import (
    TablePack,
    build_pack,
    eval_pack_ref,
    eval_pack_slope,
    make_pack_fn,
    pack_specs,
)

__all__ = [
    "DEFAULT_PACK_FUNCTIONS",
    "EXACT",
    "ApproxConfig",
    "JaxTable",
    "TablePack",
    "build_pack",
    "eval_pack_ref",
    "eval_pack_slope",
    "eval_table_ref",
    "eval_table_slope",
    "from_spec",
    "get_exact",
    "make_pack_fn",
    "make_table_fn",
    "pack_specs",
]
