"""repro.approx — JAX runtime of the paper's table-based function approximation."""

from .activations import EXACT, ApproxConfig, get_exact
from .jax_table import JaxTable, eval_table_ref, eval_table_slope, from_spec, make_table_fn

__all__ = [
    "EXACT",
    "ApproxConfig",
    "JaxTable",
    "eval_table_ref",
    "eval_table_slope",
    "from_spec",
    "get_exact",
    "make_table_fn",
]
