"""repro.approx — JAX runtime of the paper's table-based function approximation."""

from .activations import (
    DEFAULT_PACK_FUNCTIONS,
    EXACT,
    ApproxConfig,
    get_exact,
    odd_extension,
)
from .jax_table import JaxTable, eval_table_ref, eval_table_slope, from_spec, make_table_fn
from .table_pack import (
    QuantTablePack,
    TablePack,
    build_pack,
    build_quant_pack,
    eval_pack_ref,
    eval_pack_slope,
    eval_quant_pack_ref,
    eval_quant_pack_slope,
    from_quant_layout,
    make_pack_fn,
    make_quant_pack_fn,
    pack_specs,
)

__all__ = [
    "DEFAULT_PACK_FUNCTIONS",
    "EXACT",
    "ApproxConfig",
    "JaxTable",
    "QuantTablePack",
    "TablePack",
    "build_pack",
    "build_quant_pack",
    "eval_pack_ref",
    "eval_pack_slope",
    "eval_quant_pack_ref",
    "eval_quant_pack_slope",
    "from_quant_layout",
    "get_exact",
    "make_pack_fn",
    "make_quant_pack_fn",
    "make_table_fn",
    "odd_extension",
    "pack_specs",
]
