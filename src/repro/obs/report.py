"""Run summaries and run-to-run diffs over ScopeKit trace files.

A trace file is the Chrome-trace JSON ``obs.Tracer.save`` writes:
``{"traceEvents": [...], "metadata": {"metrics": {...}, ...}}``.  This module
turns it back into numbers:

* :func:`span_stats` — per-span-name aggregate (count, total/mean/max
  duration) from matched ``B``/``E`` pairs (per ``(pid, tid)`` stack) and
  ``X`` complete events;
* :func:`render_summary` — a text table of the above plus the embedded
  metrics summary (histogram percentiles, counters);
* :func:`diff_summaries` — two runs side by side with absolute and relative
  deltas, the ``tools/obs_report.py --baseline`` path.

stdlib + numpy only.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional


def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):  # bare-array form is legal Trace Event JSON
        doc = {"traceEvents": doc}
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a trace file (no traceEvents)")
    return doc


def span_stats(doc: dict) -> Dict[str, Dict[str, float]]:
    """name -> {count, total_us, mean_us, max_us, compiled} from B/E + X."""
    stacks: Dict[tuple, List[dict]] = {}
    out: Dict[str, Dict[str, float]] = {}

    def add(name: str, dur_us: float, compiled: bool) -> None:
        s = out.setdefault(name, {"count": 0, "total_us": 0.0, "max_us": 0.0,
                                  "compiled": 0})
        s["count"] += 1
        s["total_us"] += dur_us
        s["max_us"] = max(s["max_us"], dur_us)
        s["compiled"] += int(compiled)

    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "B":
            stacks.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
        elif ph == "E":
            stack = stacks.get((ev.get("pid"), ev.get("tid")))
            if stack:
                b = stack.pop()
                compiled = bool((ev.get("args") or {}).get("compiled"))
                add(b["name"], ev["ts"] - b["ts"], compiled)
        elif ph == "X":
            add(ev["name"], float(ev.get("dur", 0.0)),
                bool((ev.get("args") or {}).get("compiled")))
    for s in out.values():
        s["mean_us"] = s["total_us"] / s["count"] if s["count"] else 0.0
    return out


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.3f}ms"
    return f"{us:.1f}us"


def _table(rows: List[List[str]], header: List[str]) -> List[str]:
    widths = [max(len(header[i]), *(len(r[i]) for r in rows)) if rows
              else len(header[i]) for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines = [fmt.format(*header), fmt.format(*("-" * w for w in widths))]
    lines += [fmt.format(*r) for r in rows]
    return lines


def render_summary(doc: dict, title: str = "run") -> str:
    lines = [f"== ScopeKit summary: {title} =="]
    stats = span_stats(doc)
    if stats:
        rows = []
        for name, s in sorted(stats.items(),
                              key=lambda kv: -kv[1]["total_us"]):
            rows.append([name, str(s["count"]), _fmt_us(s["total_us"]),
                         _fmt_us(s["mean_us"]), _fmt_us(s["max_us"]),
                         str(s["compiled"])])
        lines += ["", "spans:"]
        lines += _table(rows, ["name", "count", "total", "mean", "max",
                               "compiled"])

    metrics = (doc.get("metadata") or {}).get("metrics") or {}
    hists = metrics.get("histograms") or {}
    if hists:
        rows = []
        for name, h in sorted(hists.items()):
            rows.append([name, str(h.get("count", 0))] +
                        [f"{h[k] * 1e3:.2f}ms" if k in h else "-"
                         for k in ("mean", "p50", "p95", "p99")])
        lines += ["", "latency histograms (seconds recorded, shown in ms):"]
        lines += _table(rows, ["name", "count", "mean", "p50", "p95", "p99"])
    counters = metrics.get("counters") or {}
    if counters:
        lines += ["", "counters:"]
        lines += _table([[k, str(v)] for k, v in sorted(counters.items())],
                        ["name", "value"])
    for key in ("summary", "engine"):
        extra = (doc.get("metadata") or {}).get(key)
        if extra:
            lines += ["", f"{key}:"]
            lines += [f"  {k}: {v}" for k, v in sorted(extra.items())]
    return "\n".join(lines)


def _rel(new: float, old: float) -> str:
    if old == 0:
        return "n/a" if new else "+0.0%"
    return f"{(new - old) / old * 100.0:+.1f}%"


def diff_summaries(doc_a: dict, doc_b: dict,
                   label_a: str = "baseline", label_b: str = "run") -> str:
    """Span totals and histogram percentiles of ``b`` relative to ``a``."""
    lines = [f"== ScopeKit diff: {label_b} vs {label_a} =="]
    sa, sb = span_stats(doc_a), span_stats(doc_b)
    rows = []
    for name in sorted(set(sa) | set(sb)):
        ta = sa.get(name, {}).get("total_us", 0.0)
        tb = sb.get(name, {}).get("total_us", 0.0)
        rows.append([name,
                     str(sa.get(name, {}).get("count", 0)),
                     str(sb.get(name, {}).get("count", 0)),
                     _fmt_us(ta), _fmt_us(tb), _rel(tb, ta)])
    if rows:
        lines += ["", "span totals:"]
        lines += _table(rows, ["name", f"n({label_a})", f"n({label_b})",
                               label_a, label_b, "delta"])

    ha = ((doc_a.get("metadata") or {}).get("metrics") or {}).get(
        "histograms") or {}
    hb = ((doc_b.get("metadata") or {}).get("metrics") or {}).get(
        "histograms") or {}
    rows = []
    for name in sorted(set(ha) | set(hb)):
        for q in ("p50", "p95", "p99"):
            va: Optional[float] = ha.get(name, {}).get(q)
            vb: Optional[float] = hb.get(name, {}).get(q)
            if va is None and vb is None:
                continue
            rows.append([f"{name}.{q}",
                         f"{va * 1e3:.2f}ms" if va is not None else "-",
                         f"{vb * 1e3:.2f}ms" if vb is not None else "-",
                         _rel(vb or 0.0, va or 0.0)])
    if rows:
        lines += ["", "histogram percentiles:"]
        lines += _table(rows, ["metric", label_a, label_b, "delta"])
    return "\n".join(lines)


def summarize_file(path: str, baseline: Optional[str] = None) -> str:
    doc = load_trace(path)
    if baseline is None:
        return render_summary(doc, title=path)
    return diff_summaries(load_trace(baseline), doc,
                          label_a=baseline, label_b=path)


__all__ = [
    "diff_summaries",
    "load_trace",
    "render_summary",
    "span_stats",
    "summarize_file",
]
