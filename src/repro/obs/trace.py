"""Chrome-trace-event recorder (Perfetto-loadable).

Events follow the Trace Event Format's JSON-object form: a top-level
``{"traceEvents": [...]}`` whose entries carry ``name`` / ``cat`` / ``ph`` /
``ts`` (microseconds) / ``pid`` / ``tid`` / ``args``.  We emit four phases —
``B``/``E`` duration spans, ``i`` instants, ``C`` counters, and ``M``
metadata (track names) — and guarantee two invariants the schema validator
(``tools/check_trace.py``) and the trace-schema test pin:

* per ``(pid, tid)`` track, ``B``/``E`` events are balanced and properly
  nested (``span``'s context manager makes this structural; explicit
  ``begin``/``end`` callers own it);
* timestamps are non-decreasing per track (one monotonic clock, events
  appended in order).

Track convention used by the instrumented subsystems:

=====  ======================  =======================================
pid    tid                     contents
=====  ======================  =======================================
1      0                       the driving host loop (serve/train/design)
1      100 + slot              per-request lifecycle spans, one track per
                               engine slot (requests on a slot never overlap)
=====  ======================  =======================================

The module-level helpers (:func:`span`, :func:`instant`,
:func:`counter_event`) record into the global tracer only when
``obs.configure(enabled=True)`` was called; disabled they cost one boolean
check.
"""

from __future__ import annotations

import functools
import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from . import config as _config

PID = 1
MAIN_TID = 0
SLOT_TID0 = 100  # per-request tracks: tid = SLOT_TID0 + engine slot


class Tracer:
    """Append-only event buffer over one monotonic clock."""

    def __init__(self, process_name: str = "repro"):
        self._t0 = time.perf_counter()
        self._events: List[Dict[str, Any]] = []
        self._thread_names: Dict[int, str] = {}
        self._events.append({
            "name": "process_name", "ph": "M", "pid": PID, "tid": MAIN_TID,
            "args": {"name": process_name},
        })

    # ------------------------------ clock ------------------------------------

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # ------------------------------ events -----------------------------------

    def _event(self, name: str, ph: str, cat: str, tid: int,
               ts: Optional[float] = None, **extra) -> Dict[str, Any]:
        ev = {"name": name, "cat": cat, "ph": ph,
              "ts": self.now_us() if ts is None else ts,
              "pid": PID, "tid": tid}
        ev.update(extra)
        self._events.append(ev)
        return ev

    def begin(self, name: str, cat: str = "", tid: int = MAIN_TID,
              **args) -> None:
        self._event(name, "B", cat, tid, args=args)

    def end(self, name: str, cat: str = "", tid: int = MAIN_TID,
            **args) -> None:
        self._event(name, "E", cat, tid, args=args)

    def instant(self, name: str, cat: str = "", tid: int = MAIN_TID,
                **args) -> None:
        self._event(name, "i", cat, tid, s="t", args=args)

    def counter(self, name: str, value, cat: str = "",
                tid: int = MAIN_TID) -> None:
        """One counter track per ``name``; ``value`` is a number or a dict of
        series-name -> number."""
        args = dict(value) if isinstance(value, dict) else {"value": value}
        self._event(name, "C", cat, tid, args=args)

    @contextmanager
    def span(self, name: str, cat: str = "", tid: int = MAIN_TID, **args):
        """Balanced B/E pair; extra fields set on the dict the context yields
        land on the E event's args (e.g. ``s["compiled"] = True``)."""
        self.begin(name, cat, tid, **args)
        end_args: Dict[str, Any] = {}
        try:
            yield end_args
        finally:
            self.end(name, cat, tid, **end_args)

    def set_thread_name(self, tid: int, name: str) -> None:
        if self._thread_names.get(tid) == name:
            return
        self._thread_names[tid] = name
        self._events.append({
            "name": "thread_name", "ph": "M", "pid": PID, "tid": tid,
            "args": {"name": name},
        })

    # ------------------------------ output -----------------------------------

    @property
    def events(self) -> List[Dict[str, Any]]:
        return self._events

    def clear(self) -> None:
        del self._events[:]
        self._thread_names.clear()
        self._t0 = time.perf_counter()

    def to_json(self, metadata: Optional[dict] = None) -> dict:
        out = {"traceEvents": list(self._events), "displayTimeUnit": "ms"}
        if metadata:
            out["metadata"] = metadata
        return out

    def save(self, path: str, metadata: Optional[dict] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(metadata), f, indent=1)
            f.write("\n")
        return path


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def reset_tracer() -> Tracer:
    """Fresh global tracer (new clock origin); returns it."""
    global _TRACER
    _TRACER = Tracer()
    return _TRACER


# ------------------------------------------------------------------------------
# Module-level helpers, gated on the global ObsConfig.
# ------------------------------------------------------------------------------


@contextmanager
def span(name: str, cat: str = "", tid: int = MAIN_TID, **args):
    """No-op context manager unless observability is enabled."""
    if not _config.enabled():
        yield None
        return
    with _TRACER.span(name, cat, tid, **args) as s:
        yield s


def instant(name: str, cat: str = "", tid: int = MAIN_TID, **args) -> None:
    if _config.enabled():
        _TRACER.instant(name, cat, tid, **args)


def counter_event(name: str, value, cat: str = "",
                  tid: int = MAIN_TID) -> None:
    if _config.enabled():
        _TRACER.counter(name, value, cat, tid)


def traced(name: str, cat: str = ""):
    """Decorator form of :func:`span`.  Stacked INSIDE ``lru_cache``
    (``@lru_cache`` above ``@traced``) the span fires on cache misses only —
    how the design-time pipeline phases (splitter / poly_member / quantize)
    report the work actually done rather than memo hits."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _config.enabled():
                return fn(*args, **kwargs)
            with _TRACER.span(name, cat):
                return fn(*args, **kwargs)
        return wrapper
    return deco
