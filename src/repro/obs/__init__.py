"""repro.obs — ScopeKit: tracing, metrics, and runtime error telemetry.

Three layers, all host-side stdlib/numpy (no jax import — the core design
layer may use the tracer too):

* :mod:`repro.obs.trace` — a span/event recorder emitting Chrome-trace-event
  JSON (load the file in Perfetto / ``chrome://tracing``).  The serving
  engines, the train loop, and the design-time pipeline emit spans through
  the module-level helpers (``span`` / ``instant`` / ``counter_event``),
  which are no-ops unless :func:`configure` enabled observability.
* :mod:`repro.obs.metrics` — counters / gauges / histograms with percentile
  summaries.  Engines carry their own :class:`Registry`; the global registry
  (:func:`get_registry`) receives the device-side approximation telemetry
  (out-of-domain clamp hits, routed fn_id dispatch, quant-code saturation)
  that ``repro.approx`` records via ``jax.debug.callback`` when
  ``device_telemetry`` is enabled.
* :mod:`repro.obs.report` — render a run summary from a trace file and diff
  two runs (CLI: ``tools/obs_report.py``; validation: ``tools/check_trace.py``).

The overhead contract (docs/observability.md): with :class:`ObsConfig`
disabled — the default — every hook is a cheap boolean check, no events are
recorded, no callbacks are staged, and traced jaxprs are bit-identical to a
build without ScopeKit.
"""

from .config import (
    ObsConfig,
    configure,
    device_telemetry_enabled,
    disable,
    enabled,
    get_config,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    Registry,
    get_registry,
    percentiles,
    reset_registry,
)
from .trace import (
    Tracer,
    counter_event,
    get_tracer,
    instant,
    reset_tracer,
    span,
    traced,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "ObsConfig",
    "Registry",
    "Tracer",
    "configure",
    "counter_event",
    "device_telemetry_enabled",
    "disable",
    "enabled",
    "get_config",
    "get_registry",
    "get_tracer",
    "instant",
    "percentiles",
    "reset_registry",
    "reset_tracer",
    "span",
    "traced",
]
