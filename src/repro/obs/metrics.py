"""Counters, gauges, and histograms with percentile summaries.

A :class:`Registry` is a flat name -> instrument map.  Two registries matter:

* each serving engine owns one (``engine.metrics``) — TTFT, inter-token
  latency, queue wait, per-span timings; ``engine.reset_counters()`` clears
  it together with the batch/wasted-step integers;
* the process-global one (:func:`get_registry`) receives the device-side
  approximation telemetry recorded by ``repro.approx`` through
  ``jax.debug.callback`` (counter names: ``approx.oob.<fn>`` /
  ``approx.lookups.<fn>`` clamp-or-extrapolation hits out of total lookups,
  ``approx.routed.<fn>`` routed rows dispatched per member, and
  ``approx.quant_sat.<fn>`` / ``approx.quant_gathers.<fn>`` saturated
  endpoint codes out of total code gathers).

Everything here is stdlib + numpy — importable from the f64 design layer and
from inside host callbacks without touching jax.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

# Histograms keep raw observations up to this many samples, then reservoir-
# decimate by dropping every other retained sample (percentiles stay honest
# to ~1% for the serving workloads this instrument; the cap only exists so a
# week-long engine cannot grow without bound).
HIST_CAP = 1 << 20


def percentiles(values: Iterable[float],
                qs=(50.0, 95.0, 99.0)) -> Dict[str, float]:
    """{"p50": ..., "p95": ..., "p99": ...} (empty input -> {})."""
    arr = np.asarray(list(values), dtype=np.float64)
    if arr.size == 0:
        return {}
    return {f"p{q:g}": float(np.percentile(arr, q)) for q in qs}


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def add(self, n=1) -> None:
        self.value += n

    def summary(self):
        return self.value


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v

    def summary(self):
        return self.value


class Histogram:
    __slots__ = ("values", "count", "_stride")

    def __init__(self):
        self.values: List[float] = []
        self.count = 0  # total observed, including decimated-away samples
        self._stride = 1

    def observe(self, v: float) -> None:
        self.count += 1
        if self.count % self._stride == 0:
            self.values.append(float(v))
            if len(self.values) >= HIST_CAP:
                self.values = self.values[::2]
                self._stride *= 2

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"count": self.count}
        if self.values:
            arr = np.asarray(self.values)
            out.update(mean=float(arr.mean()), min=float(arr.min()),
                       max=float(arr.max()))
            out.update(percentiles(arr))
        return out


class Registry:
    """Flat name -> instrument map with get-or-create accessors."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._histograms.setdefault(name, Histogram())

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()

    def summary(self) -> dict:
        """JSON-ready snapshot: {"counters": {...}, "gauges": {...},
        "histograms": {name: {count, mean, p50, p95, p99, ...}}}."""
        return {
            "counters": {k: c.summary() for k, c in
                         sorted(self._counters.items())},
            "gauges": {k: g.summary() for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary() for k, h in
                           sorted(self._histograms.items())},
        }


_REGISTRY = Registry()


def get_registry() -> Registry:
    return _REGISTRY


def reset_registry() -> Registry:
    _REGISTRY.reset()
    return _REGISTRY


def merge_summaries(base: Optional[dict], *others: dict) -> dict:
    """Sum counters across registry summaries (gauges/histograms keep the
    last non-empty value) — the fleet-aggregation shape ROADMAP's multi-
    replica item will feed per-replica summaries through."""
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    for s in (base, *others):
        if not s:
            continue
        for k, v in s.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        out["gauges"].update(s.get("gauges", {}))
        out["histograms"].update(s.get("histograms", {}))
    return out
