"""ScopeKit's one switch: a process-global :class:`ObsConfig`.

Observability is OFF by default.  Enabling it is a host-side decision made
once per process (CLIs do it from ``--trace`` / ``--obs``); the two flags are
independent layers:

* ``enabled`` — host-side spans and metrics.  Pure Python bookkeeping: no
  device computation, no jaxpr change, no recompiles.  Engines re-check it on
  every ``serve()`` / ``run()`` entry, so flipping it between calls works
  without rebuilding anything.
* ``device_telemetry`` — the approximation-error telemetry recorded from
  inside jitted computations via ``jax.debug.callback`` (out-of-domain clamp
  hits, routed fn_id dispatch histogram, quant-code saturation).  This one IS
  captured at activation-closure build time (``ApproxConfig.unary`` /
  ``routed_fn``): enabling it after a model was built has no effect on that
  model — rebuild the closures (or the model) to instrument them.  The off
  path returns the un-wrapped callable, so the traced jaxpr is bit-identical
  to a build without ScopeKit and no extra executables appear.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

_UNSET = object()


@dataclass(frozen=True)
class ObsConfig:
    enabled: bool = False
    device_telemetry: bool = False
    trace_path: Optional[str] = None  # where CLIs write the trace artifact


_CONFIG = ObsConfig()


def configure(enabled=_UNSET, device_telemetry=_UNSET,
              trace_path=_UNSET) -> ObsConfig:
    """Update the process-global config; only passed fields change."""
    global _CONFIG
    kw = {}
    if enabled is not _UNSET:
        kw["enabled"] = bool(enabled)
    if device_telemetry is not _UNSET:
        kw["device_telemetry"] = bool(device_telemetry)
    if trace_path is not _UNSET:
        kw["trace_path"] = trace_path
    _CONFIG = replace(_CONFIG, **kw)
    return _CONFIG


def disable() -> ObsConfig:
    """Back to the all-off default (tests restore state through this)."""
    global _CONFIG
    _CONFIG = ObsConfig()
    return _CONFIG


def get_config() -> ObsConfig:
    return _CONFIG


def enabled() -> bool:
    return _CONFIG.enabled


def device_telemetry_enabled() -> bool:
    """Device-side telemetry needs BOTH flags: it records into the metrics
    layer, which only exists as a consumer when observability is on."""
    return _CONFIG.enabled and _CONFIG.device_telemetry
