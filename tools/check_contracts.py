#!/usr/bin/env python
"""PackLint CLI: statically verify the repo's standing contracts.

Traces (never executes) every registered (mode x function x {value, grad})
closure and checks the five contract rules in ``repro.analysis.contracts``:
f64 leakage, kernel primitive allowlists, recompile hazards, static VMEM
budgets, and the obs-off zero-overhead identity.  Writes
``REPORT_contracts.json`` and exits non-zero on any violation.

Usage:
    PYTHONPATH=src python tools/check_contracts.py            # full matrix
    PYTHONPATH=src python tools/check_contracts.py --fast     # CI fast tier
    PYTHONPATH=src python tools/check_contracts.py --rules vmem_budget
    PYTHONPATH=src python tools/check_contracts.py --list-rules
"""

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", action="store_true",
                    help="subsample the function axis to the conformance "
                         "fast-tier trio (gelu, tanh, log)")
    ap.add_argument("--funcs", default=None,
                    help="comma-separated function subset (overrides --fast)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--e-a", type=float, default=None,
                    help="design error bound for the checked packs "
                         "(default 1e-4)")
    ap.add_argument("--out", default="REPORT_contracts.json",
                    help="report path ('' to skip writing)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    from repro.analysis import contracts

    if args.list_rules:
        for name, fn in contracts.RULES.items():
            doc = (fn.__doc__ or "").strip().split("\n")[0]
            print(f"{name:<20} {doc}")
        return 0

    funcs = None
    if args.funcs:
        funcs = tuple(f.strip() for f in args.funcs.split(",") if f.strip())
    elif args.fast:
        funcs = contracts.FAST_FUNCS
    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in contracts.RULES]
        if unknown:
            print(f"unknown rules: {unknown}; have {list(contracts.RULES)}",
                  file=sys.stderr)
            return 2

    t0 = time.perf_counter()
    ctx = contracts.LintContext(
        e_a=args.e_a if args.e_a is not None else contracts.EA, funcs=funcs)
    rep = contracts.run(ctx, rules=rules)
    rep.meta["elapsed_s"] = round(time.perf_counter() - t0, 2)
    rep.meta["tier"] = "fast" if funcs is not None else "full"

    if args.out:
        rep.to_json(args.out)
        print(f"wrote {args.out}")
    print(rep.summary())
    return 0 if rep.ok else 1


if __name__ == "__main__":
    sys.exit(main())
