"""Trace-file gate: validate a ScopeKit Chrome-trace JSON artifact.

CI's fast tier produces ``TRACE_serve.json`` from a reduced continuous-serve
run and pipes it through this script before uploading it; the trace-schema
test reuses :func:`validate_trace` directly.  Checks, per the Trace Event
Format plus ScopeKit's own invariants:

* top level is ``{"traceEvents": [...]}`` (or the bare-array form);
* every event has ``name`` / ``ph`` / ``pid`` / ``tid``, a numeric ``ts``
  (metadata ``M`` events are exempt from ``ts``), and a known phase;
* per ``(pid, tid)`` track: ``B``/``E`` balanced and properly nested, and
  timestamps non-decreasing;
* ``X`` events carry a non-negative ``dur``; ``C`` events carry a dict of
  numeric series.

Run:  python tools/check_trace.py TRACE_serve.json
"""

from __future__ import annotations

import json
import numbers
import sys

KNOWN_PHASES = frozenset("BEXiICMbne")


def validate_trace(doc) -> list[str]:
    """Return a list of human-readable schema violations (empty == clean)."""
    errors: list[str] = []
    if isinstance(doc, list):
        events = doc
    elif isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["top level has no traceEvents array"]
    else:
        return ["top level is neither an object nor an array"]
    if not events:
        errors.append("traceEvents is empty")

    stacks: dict[tuple, list] = {}
    last_ts: dict[tuple, float] = {}
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing name")
            name = "?"
        where = f"event[{i}] {ph}:{name}"
        if ph not in KNOWN_PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), numbers.Number):
                errors.append(f"{where}: missing numeric {field}")
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "M":
            continue  # metadata events carry no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, numbers.Number):
            errors.append(f"{where}: missing numeric ts")
            continue
        if ts < last_ts.get(track, float("-inf")):
            errors.append(f"{where}: ts went backwards on track {track} "
                          f"({ts} < {last_ts[track]})")
        last_ts[track] = ts
        if ph == "B":
            stacks.setdefault(track, []).append((name, ts))
        elif ph == "E":
            stack = stacks.get(track)
            if not stack:
                errors.append(f"{where}: E without matching B on track "
                              f"{track}")
            else:
                open_name, open_ts = stack.pop()
                if open_name != name:
                    errors.append(
                        f"{where}: E closes {name!r} but innermost open span "
                        f"on track {track} is {open_name!r} (not nested)")
                if ts < open_ts:
                    errors.append(f"{where}: span ends before it begins")
        elif ph == "X":
            dur = ev.get("dur", 0)
            if not isinstance(dur, numbers.Number) or dur < 0:
                errors.append(f"{where}: X needs a non-negative dur")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, numbers.Number) for v in args.values()):
                errors.append(f"{where}: C needs a dict of numeric series")
    for track, stack in stacks.items():
        for open_name, _ in stack:
            errors.append(f"track {track}: span {open_name!r} never ended "
                          f"(unbalanced B/E)")
    return errors


def main(argv: list[str]) -> None:
    if len(argv) != 1:
        print("usage: python tools/check_trace.py TRACE.json")
        raise SystemExit(2)
    path = argv[0]
    with open(path) as f:
        doc = json.load(f)
    errors = validate_trace(doc)
    if errors:
        print(f"trace check FAILED: {path}")
        for e in errors[:50]:
            print(f"  - {e}")
        if len(errors) > 50:
            print(f"  ... and {len(errors) - 50} more")
        raise SystemExit(1)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    tracks = {(e.get("pid"), e.get("tid")) for e in events}
    print(f"trace check OK: {path} — {len(events)} events on "
          f"{len(tracks)} tracks")


if __name__ == "__main__":
    main(sys.argv[1:])
