"""Render a ScopeKit run summary — or diff two runs — from trace files.

Run:  PYTHONPATH=src python tools/obs_report.py TRACE_serve.json
      PYTHONPATH=src python tools/obs_report.py TRACE_new.json --baseline TRACE_old.json

The heavy lifting lives in ``repro.obs.report`` (span aggregation from
matched B/E pairs, metric-percentile tables, relative deltas); this is the
thin CLI over it.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs.report import summarize_file  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="ScopeKit Chrome-trace JSON file")
    ap.add_argument("--baseline", default=None,
                    help="second trace to diff against (prints deltas)")
    args = ap.parse_args()
    try:
        print(summarize_file(args.trace, baseline=args.baseline))
    except BrokenPipeError:  # e.g. piped into head; not an error
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())


if __name__ == "__main__":
    main()
