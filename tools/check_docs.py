"""Docs-drift gate: the mode matrices must cover every ApproxConfig mode.

The cross-mode conformance suite pins the CODE side of a new mode (it must
join ``repro.approx.TABLE_MODES`` or tests/test_conformance.py fails); this
script pins the DOCS side, in both directions:

- forward drift: every mode — ``exact`` plus the whole of ``TABLE_MODES`` —
  must appear as a backticked row in BOTH the full matrix in
  docs/architecture.md and the summary matrix in README.md, and every doc
  page the architecture matrix links must exist;
- reverse drift: every row of those mode matrices must name a mode that the
  registry still exposes, so renaming or retiring a mode without pruning its
  doc rows fails just as loudly as adding one without documenting it;
- bench reports: every committed repo-root ``BENCH_*.json`` must have a
  schema section in benchmarks/README.md.

CI runs it next to the bench smokes, so a PR that adds a mode without
documenting it fails fast.

Run:  PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.approx import TABLE_MODES  # noqa: E402

ALL_MODES = ("exact",) + tuple(TABLE_MODES)

MATRIX_FILES = (
    os.path.join(REPO, "docs", "architecture.md"),
    os.path.join(REPO, "README.md"),
)


def matrix_rows(path: str) -> list[str]:
    """Markdown table rows (lines starting with '|') of the file."""
    with open(path) as f:
        return [line for line in f if line.lstrip().startswith("|")]


def missing_modes(path: str) -> list[str]:
    rows = matrix_rows(path)
    missing = []
    for mode in ALL_MODES:
        cell = f"`{mode}`"
        if not any(cell in row for row in rows):
            missing.append(mode)
    return missing


def mode_matrix_first_cells(path: str) -> list[str]:
    """Backticked first-cell tokens of every data row in the mode matrices.

    A mode matrix is any markdown table whose header row's first cell is
    literally ``mode``; other tables in the same file are ignored.
    """
    cells = []
    in_matrix = False
    with open(path) as f:
        for line in f:
            stripped = line.lstrip()
            if not stripped.startswith("|"):
                in_matrix = False
                continue
            first = stripped.split("|")[1].strip()
            if first == "mode":
                in_matrix = True
                continue
            if not in_matrix or set(first) <= {"-", ":", " "}:
                continue
            m = re.fullmatch(r"`([^`]+)`", first)
            if m:
                cells.append(m.group(1))
    return cells


def unknown_modes(path: str) -> list[str]:
    """Mode-matrix rows whose mode is not in the live registry."""
    return [c for c in mode_matrix_first_cells(path) if c not in ALL_MODES]


def undocumented_bench_reports() -> list[str]:
    """Repo-root BENCH_*.json files with no schema section in benchmarks/README.md."""
    readme = os.path.join(REPO, "benchmarks", "README.md")
    if not os.path.exists(readme):
        return sorted(
            f for f in os.listdir(REPO)
            if f.startswith("BENCH_") and f.endswith(".json"))
    with open(readme) as f:
        text = f.read()
    return sorted(
        f for f in os.listdir(REPO)
        if f.startswith("BENCH_") and f.endswith(".json") and f not in text)


def dangling_links(path: str) -> list[str]:
    """Relative .md links in the file that do not resolve on disk."""
    with open(path) as f:
        text = f.read()
    out = []
    for target in re.findall(r"\]\(([^)#]+\.md)\)", text):
        if target.startswith("http"):
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            out.append(target)
    return out


def main() -> None:
    failures = []
    for path in MATRIX_FILES:
        rel = os.path.relpath(path, REPO)
        if not os.path.exists(path):
            failures.append(f"{rel}: file missing")
            continue
        miss = missing_modes(path)
        if miss:
            failures.append(
                f"{rel}: mode matrix is missing {miss} — every ApproxConfig "
                f"mode must appear as a backticked table row")
        unknown = unknown_modes(path)
        if unknown:
            failures.append(
                f"{rel}: mode matrix rows {unknown} are not registered "
                f"ApproxConfig modes — prune or rename the doc rows")
        dead = dangling_links(path)
        if dead:
            failures.append(f"{rel}: dangling doc links {dead}")
    orphans = undocumented_bench_reports()
    if orphans:
        failures.append(
            f"benchmarks/README.md: no schema section for {orphans} — every "
            f"committed BENCH_*.json must be documented there")
    if failures:
        print("docs drift check FAILED:")
        for f in failures:
            print(f"  - {f}")
        raise SystemExit(1)
    print(f"docs drift check OK: {len(ALL_MODES)} modes covered in "
          f"{', '.join(os.path.relpath(p, REPO) for p in MATRIX_FILES)}")


if __name__ == "__main__":
    main()
