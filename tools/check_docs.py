"""Docs-drift gate: the mode matrices must cover every ApproxConfig mode.

The cross-mode conformance suite pins the CODE side of a new mode (it must
join ``repro.approx.TABLE_MODES`` or tests/test_conformance.py fails); this
script pins the DOCS side: every mode — ``exact`` plus the whole of
``TABLE_MODES`` — must appear as a backticked row in BOTH the full matrix in
docs/architecture.md and the summary matrix in README.md, and every doc page
the architecture matrix links must exist.  CI runs it next to the bench
smokes, so a PR that adds a mode without documenting it fails fast.

Run:  PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.approx import TABLE_MODES  # noqa: E402

ALL_MODES = ("exact",) + tuple(TABLE_MODES)

MATRIX_FILES = (
    os.path.join(REPO, "docs", "architecture.md"),
    os.path.join(REPO, "README.md"),
)


def matrix_rows(path: str) -> list[str]:
    """Markdown table rows (lines starting with '|') of the file."""
    with open(path) as f:
        return [line for line in f if line.lstrip().startswith("|")]


def missing_modes(path: str) -> list[str]:
    rows = matrix_rows(path)
    missing = []
    for mode in ALL_MODES:
        cell = f"`{mode}`"
        if not any(cell in row for row in rows):
            missing.append(mode)
    return missing


def dangling_links(path: str) -> list[str]:
    """Relative .md links in the file that do not resolve on disk."""
    with open(path) as f:
        text = f.read()
    out = []
    for target in re.findall(r"\]\(([^)#]+\.md)\)", text):
        if target.startswith("http"):
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            out.append(target)
    return out


def main() -> None:
    failures = []
    for path in MATRIX_FILES:
        rel = os.path.relpath(path, REPO)
        if not os.path.exists(path):
            failures.append(f"{rel}: file missing")
            continue
        miss = missing_modes(path)
        if miss:
            failures.append(
                f"{rel}: mode matrix is missing {miss} — every ApproxConfig "
                f"mode must appear as a backticked table row")
        dead = dangling_links(path)
        if dead:
            failures.append(f"{rel}: dangling doc links {dead}")
    if failures:
        print("docs drift check FAILED:")
        for f in failures:
            print(f"  - {f}")
        raise SystemExit(1)
    print(f"docs drift check OK: {len(ALL_MODES)} modes covered in "
          f"{', '.join(os.path.relpath(p, REPO) for p in MATRIX_FILES)}")


if __name__ == "__main__":
    main()
